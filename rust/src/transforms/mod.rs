//! Data-centric graph transformations (paper §3).
//!
//! Transformations are checked graph rewrites, DaCe-style: each has a
//! `can_apply` feasibility check and an `apply` mutation, and the pass
//! manager re-validates the graph after every application so a rewrite
//! can never corrupt it.
//!
//! * [`vectorize::Vectorize`] — traditional vectorization (Figure 3 ①):
//!   divides the map range by V and widens container types;
//! * [`streaming::StreamingComposition`] — converts memory dependencies
//!   to queue access, injecting reader/writer modules (Figure 3 ②);
//! * [`multipump::MultiPump`] — the paper's contribution (Figure 3 ③):
//!   places the streamed computational subgraph in a faster clock
//!   domain and injects synchronizer/issuer/packer plumbing. Every
//!   region carries its own [`crate::ir::RegionPump`] `{factor, mode}`:
//!   resource mode narrows widths inside the fast domain, throughput
//!   mode widens the external interface, and bare-fast mode changes no
//!   widths at all — the fast clock recovers loop-carried II with
//!   zero issuer/packer gearboxes. Supports both the paper's §3.4
//!   whole-subgraph factor and *mixed* per-region assignments
//!   ([`multipump::PumpFactors::PerRegion`]) with full crossings
//!   between fast domains of different ratios and modes.

pub mod multipump;
pub mod pass;
pub mod streaming;
pub mod vectorize;

pub use multipump::{MultiPump, PumpFactors};
pub use pass::{PassManager, Transform, TransformReport};
pub use streaming::StreamingComposition;
pub use vectorize::Vectorize;
