//! Streaming composition (paper Figure 3, box ②).
//!
//! *"the streaming transformation extracts the reads (writes) out of
//! the computation by introducing other components that access x and y
//! (z) in the same order as the computation, and push (pop) the values
//! into streams. [...] Now that the communication on the streams drives
//! control flow, all the four components (two readers, compute, and
//! writer) can run in parallel."*
//!
//! Three rewrites compose:
//! 1. external array reads of a compute scope become
//!    `access → Reader → stream → scope`;
//! 2. external array writes become `scope → stream → Writer → access`;
//! 3. transient arrays between two compute modules (stencil chain
//!    stages) become direct streams.

use super::pass::{Transform, TransformReport};
use crate::analysis::movement::scope_movement;
use crate::analysis::streamability::{streamable_access, Streamability};
use crate::ir::{
    ContainerKind, DataDecl, Memlet, Node, NodeId, Sdfg, Storage,
};
use crate::symbolic::{Expr, Range, Subset};

/// Stream depth for injected FIFOs (transactions). The paper relies on
/// the Xilinx AXI infra defaults; 16 covers CDC latency comfortably.
pub const DEFAULT_STREAM_DEPTH: usize = 16;

/// Convert the whole application to streaming form (greedy, §3.4).
pub struct StreamingComposition {
    pub stream_depth: usize,
}

impl Default for StreamingComposition {
    fn default() -> Self {
        StreamingComposition { stream_depth: DEFAULT_STREAM_DEPTH }
    }
}

/// Compute "modules" at the streaming level: map scopes (by entry) and
/// library nodes.
fn compute_modules(g: &Sdfg) -> Vec<NodeId> {
    g.node_ids()
        .filter(|id| {
            matches!(g.node(*id), Node::MapEntry { .. } | Node::Library { .. })
        })
        .collect()
}

/// The boundary node data flows into for a module (entry for maps,
/// the node itself for libraries), and out of (exit / itself).
fn module_io(g: &Sdfg, id: NodeId) -> (NodeId, NodeId) {
    match g.node(id) {
        Node::MapEntry { name, .. } => (id, g.find_map_exit(name).expect("validated")),
        _ => (id, id),
    }
}

impl StreamingComposition {
    /// Check one module's external accesses for streamability; returns
    /// the list of (container, is_read) conversions it would perform.
    fn plan_module(&self, g: &Sdfg, module: NodeId) -> Result<Vec<(String, bool)>, String> {
        let mut plan = Vec::new();
        match g.node(module) {
            Node::MapEntry { .. } => {
                let mv = scope_movement(g, module)?;
                for acc in mv.all() {
                    let decl = g
                        .container(&acc.data)
                        .ok_or_else(|| format!("unknown container {}", acc.data))?;
                    if decl.kind == ContainerKind::Stream {
                        continue; // already a stream
                    }
                    match streamable_access(acc, mv.inner_param()) {
                        Streamability::Streamable { .. } => {
                            plan.push((acc.data.clone(), acc.is_read))
                        }
                        Streamability::Blocked(r) => {
                            return Err(format!("module {}: {r}", g.node(module).label()))
                        }
                    }
                }
                // a container must not be accessed under two different
                // subsets (stencil neighbours need library nodes with
                // internal line buffers, not plain streaming)
                for acc in mv.reads.iter() {
                    let same: Vec<_> =
                        mv.reads.iter().filter(|a| a.data == acc.data).collect();
                    if same.len() > 1
                        && same
                            .iter()
                            .any(|a| a.subset.same_as(&acc.subset) != Some(true))
                    {
                        return Err(format!(
                            "container '{}' read under multiple subsets; requires a library node with line buffers",
                            acc.data
                        ));
                    }
                }
            }
            Node::Library { .. } => {
                // library nodes access their arrays linearly by
                // construction (feeders/drainers); all arrays qualify
                for e in g.in_edges(module) {
                    let data = g.edge(e).memlet.data.clone();
                    if g.container(&data).map(|d| d.kind) == Some(ContainerKind::Array) {
                        plan.push((data, true));
                    }
                }
                for e in g.out_edges(module) {
                    let data = g.edge(e).memlet.data.clone();
                    if g.container(&data).map(|d| d.kind) == Some(ContainerKind::Array) {
                        plan.push((data, false));
                    }
                }
            }
            _ => {}
        }
        Ok(plan)
    }
}

impl Transform for StreamingComposition {
    fn name(&self) -> String {
        "StreamingComposition".into()
    }

    fn can_apply(&self, g: &Sdfg) -> Result<(), String> {
        let modules = compute_modules(g);
        if modules.is_empty() {
            return Err("no computational modules".into());
        }
        if g.node_ids().any(|id| g.node(id).is_io_module()) {
            return Err("already streamed".into());
        }
        let mut any = false;
        for m in modules {
            if !self.plan_module(g, m)?.is_empty() {
                any = true;
            }
        }
        if !any {
            return Err("no external array accesses to stream".into());
        }
        Ok(())
    }

    fn apply(&self, g: &mut Sdfg) -> Result<TransformReport, String> {
        let modules = compute_modules(g);
        let mut readers = 0usize;
        let mut writers = 0usize;
        let mut fused = 0usize;

        // 3. transient arrays between two compute modules → streams
        //    (detected as: access node with ≥1 compute producer and ≥1
        //    compute consumer, container transient)
        let mut inter: Vec<NodeId> = Vec::new();
        for id in g.node_ids() {
            if let Node::Access { data } = g.node(id) {
                let decl = g.container(data).unwrap();
                if !decl.transient || decl.kind != ContainerKind::Array {
                    continue;
                }
                let has_producer = !g.in_edges(id).is_empty();
                let has_consumer = !g.out_edges(id).is_empty();
                if has_producer && has_consumer {
                    inter.push(id);
                }
            }
        }
        for id in inter {
            let data = match g.node(id) {
                Node::Access { data } => data.clone(),
                _ => unreachable!(),
            };
            let decl = g.containers.get_mut(&data).unwrap();
            decl.kind = ContainerKind::Stream;
            decl.storage = Storage::Stream { depth: self.stream_depth };
            decl.shape = vec![];
            fused += 1;
        }

        // 1 & 2: wrap external arrays of every module with Reader/Writer
        for module in modules {
            let plan = self.plan_module(g, module)?;
            let (inflow, outflow) = module_io(g, module);
            for (data, is_read) in plan {
                let decl = g.container(&data).unwrap().clone();
                if decl.kind == ContainerKind::Stream {
                    continue; // converted by step 3 already
                }
                let vtype = decl.vtype;
                let full = Subset::new(
                    decl.shape
                        .iter()
                        .map(|d| Range::new(Expr::int(0), d.clone(), 1))
                        .collect(),
                );
                if is_read {
                    let sname = format!("{data}_to_{}", g.node(module).label());
                    g.declare(DataDecl {
                        name: sname.clone(),
                        kind: ContainerKind::Stream,
                        vtype,
                        shape: vec![],
                        storage: Storage::Stream { depth: self.stream_depth },
                        transient: true,
                    });
                    let rd = g.add_node(Node::Reader {
                        name: format!("read_{data}"),
                        data: data.clone(),
                        stream: sname.clone(),
                    });
                    let sa = g.add_node(Node::Access { data: sname.clone() });
                    // original access node feeding the module
                    let src_access = g
                        .in_edges(inflow)
                        .into_iter()
                        .map(|e| g.edge(e).src)
                        .find(|n| matches!(g.node(*n), Node::Access { data: d } if *d == data));
                    let src_access = match src_access {
                        Some(a) => a,
                        None => continue, // already rewired (shared container)
                    };
                    // preserve the original inner connector name
                    let inner_conn = g
                        .in_edges(inflow)
                        .iter()
                        .find_map(|e| {
                            let edge = g.edge(*e);
                            if edge.src == src_access && edge.memlet.data == data {
                                edge.memlet.dst_conn.clone()
                            } else {
                                None
                            }
                        });
                    g.retain_edges(|e| {
                        !(e.src == src_access && e.dst == inflow && e.memlet.data == data)
                    });
                    g.add_edge(src_access, rd, Memlet::new(&data, full.clone()));
                    g.add_edge(rd, sa, Memlet::new(&sname, Subset::index1(Expr::int(0))));
                    let mut to_module = Memlet::new(&sname, Subset::index1(Expr::int(0)));
                    to_module.dst_conn = inner_conn;
                    g.add_edge(sa, inflow, to_module);
                    // rewrite inner edges (entry → tasklet) to pop the
                    // stream. Library nodes have no inner edges (inflow
                    // == outflow == the node), so skip them — rewriting
                    // there would clobber the node's output edge.
                    if inflow != outflow {
                        for eid in g.edge_ids().collect::<Vec<_>>() {
                            let e = g.edge(eid);
                            if e.src == inflow && e.memlet.data == data {
                                let conn = e.memlet.dst_conn.clone();
                                let em = g.edge_mut(eid);
                                em.memlet = Memlet {
                                    data: sname.clone(),
                                    subset: Subset::index1(Expr::int(0)),
                                    src_conn: None,
                                    dst_conn: conn,
                                    dynamic: false,
                                };
                            }
                        }
                    }
                    readers += 1;
                } else {
                    let sname = format!("{data}_from_{}", g.node(module).label());
                    g.declare(DataDecl {
                        name: sname.clone(),
                        kind: ContainerKind::Stream,
                        vtype,
                        shape: vec![],
                        storage: Storage::Stream { depth: self.stream_depth },
                        transient: true,
                    });
                    let wr = g.add_node(Node::Writer {
                        name: format!("write_{data}"),
                        data: data.clone(),
                        stream: sname.clone(),
                    });
                    let sa = g.add_node(Node::Access { data: sname.clone() });
                    let dst_access = g
                        .out_edges(outflow)
                        .into_iter()
                        .map(|e| g.edge(e).dst)
                        .find(|n| matches!(g.node(*n), Node::Access { data: d } if *d == data));
                    let dst_access = match dst_access {
                        Some(a) => a,
                        None => continue,
                    };
                    let inner_conn = g
                        .out_edges(outflow)
                        .iter()
                        .find_map(|e| {
                            let edge = g.edge(*e);
                            if edge.dst == dst_access && edge.memlet.data == data {
                                edge.memlet.src_conn.clone()
                            } else {
                                None
                            }
                        });
                    g.retain_edges(|e| {
                        !(e.src == outflow && e.dst == dst_access && e.memlet.data == data)
                    });
                    let mut from_module = Memlet::new(&sname, Subset::index1(Expr::int(0)));
                    from_module.src_conn = inner_conn;
                    g.add_edge(outflow, sa, from_module);
                    g.add_edge(sa, wr, Memlet::new(&sname, Subset::index1(Expr::int(0))));
                    g.add_edge(wr, dst_access, Memlet::new(&data, full.clone()));
                    // rewrite inner edges (tasklet → exit); skip library
                    // nodes (no inner edges)
                    if inflow != outflow {
                        for eid in g.edge_ids().collect::<Vec<_>>() {
                            let e = g.edge(eid);
                            if e.dst == outflow && e.memlet.data == data {
                                let conn = e.memlet.src_conn.clone();
                                let em = g.edge_mut(eid);
                                em.memlet = Memlet {
                                    data: sname.clone(),
                                    subset: Subset::index1(Expr::int(0)),
                                    src_conn: conn,
                                    dst_conn: None,
                                    dynamic: false,
                                };
                            }
                        }
                    }
                    writers += 1;
                }
            }
        }

        Ok(TransformReport {
            transform: self.name(),
            summary: format!(
                "{readers} readers, {writers} writers injected, {fused} transient arrays fused to streams"
            ),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::vecadd_sdfg;
    use crate::ir::validate::validate;
    use crate::transforms::pass::PassManager;

    #[test]
    fn vecadd_streams_into_four_components() {
        let mut g = vecadd_sdfg(1);
        let mut pm = PassManager::new();
        let report = pm.run(&mut g, &StreamingComposition::default()).unwrap().clone();
        validate(&g).unwrap();
        assert!(report.summary.contains("2 readers"), "{}", report.summary);
        assert!(report.summary.contains("1 writers"), "{}", report.summary);
        // paper: two readers, compute, writer
        let readers = g
            .node_ids()
            .filter(|i| matches!(g.node(*i), Node::Reader { .. }))
            .count();
        let writers = g
            .node_ids()
            .filter(|i| matches!(g.node(*i), Node::Writer { .. }))
            .count();
        assert_eq!((readers, writers), (2, 1));
        // inner tasklet edges now pop streams
        let t = g
            .node_ids()
            .find(|i| matches!(g.node(*i), Node::Tasklet(_)))
            .unwrap();
        for e in g.in_edges(t) {
            let d = &g.edge(e).memlet.data;
            assert!(g.container(d).unwrap().kind == ContainerKind::Stream, "{d}");
        }
    }

    #[test]
    fn idempotence_guard() {
        let mut g = vecadd_sdfg(1);
        let mut pm = PassManager::new();
        pm.run(&mut g, &StreamingComposition::default()).unwrap();
        let err = pm
            .run(&mut g, &StreamingComposition::default())
            .unwrap_err();
        assert!(err.contains("already streamed"), "{err}");
    }

    #[test]
    fn stencil_neighbours_rejected_for_plain_maps() {
        // 1-D smooth via the DSL: reads a[i-1], a[i], a[i+1]
        let src = "
program smooth(N):
  a: f32[N] @ hbm
  b: f32[N] @ hbm
  map i in 1:N-1:
    b[i] = 0.25 * a[i-1] + 0.5 * a[i] + 0.25 * a[i+1]
";
        let g = crate::frontend::compile(src).unwrap();
        let err = StreamingComposition::default().can_apply(&g).unwrap_err();
        assert!(err.contains("multiple subsets"), "{err}");
    }
}
