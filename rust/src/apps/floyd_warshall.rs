//! Floyd–Warshall (paper §4.4, Table 6): the program that cannot be
//! traditionally vectorized — multi-pumping applies in *throughput*
//! mode, preserving the dependent computation while feeding it wider.

use crate::ir::{DType, GraphBuilder, LibraryOp, Memlet, Sdfg, VecType};
use crate::symbolic::{Expr, Range, Subset};

/// Paper problem: 500-node graph.
pub const PAPER_N: i64 = 500;

/// Verification-scale size matching the AOT artifact.
pub const GOLDEN_N: i64 = 64;

/// Finite "infinity" sentinel (hardware adders never see inf/nan).
pub const INF: f32 = 1.0e30;

/// Build the FW SDFG: dist streams through the relaxation datapath
/// once per outer k iteration (the repeat wrapper).
pub fn build() -> Sdfg {
    let mut b = GraphBuilder::new("floyd_warshall");
    let vt = VecType::scalar(DType::F32);
    b.array("dist", vt, vec![Expr::sym("N"), Expr::sym("N")]);
    let d_in = b.access("dist");
    let d_out = b.access("dist");
    let lib = b.library("fw_relax", LibraryOp::FloydWarshall { lanes: 1 });
    let full = Subset::new(vec![Range::upto_sym("N"), Range::upto_sym("N")]);
    b.edge(d_in, lib, Memlet::new("dist", full.clone()).with_dst("d"));
    b.edge(lib, d_out, Memlet::new("dist", full).with_src("d_out"));
    b.repeat("k", Range::upto_sym("N"));
    b.finish()
}

/// Flops: n³ relaxations × (1 add + 1 min).
pub fn flops(n: i64) -> f64 {
    2.0 * (n as f64).powi(3)
}

/// Random weighted digraph in dense matrix form, INF-sentineled.
pub fn random_graph(n: usize, seed: u64, density: f64) -> Vec<f32> {
    let mut rng = crate::util::Rng::new(seed);
    let mut d = vec![INF; n * n];
    for i in 0..n {
        d[i * n + i] = 0.0;
    }
    for i in 0..n {
        for j in 0..n {
            if i != j && rng.f64() < density {
                d[i * n + j] = rng.f32_range(0.1, 10.0);
            }
        }
    }
    d
}

/// Reference CPU Floyd–Warshall (golden for tests).
pub fn reference(d: &[f32], n: usize) -> Vec<f32> {
    let mut out = d.to_vec();
    for k in 0..n {
        for i in 0..n {
            let dik = out[i * n + k];
            if dik >= INF {
                continue;
            }
            for j in 0..n {
                let cand = dik + out[k * n + j];
                if cand < out[i * n + j] {
                    out[i * n + j] = cand;
                }
            }
        }
    }
    out
}

/// Paper Table 6: (variant, CL0, CL1, time_s, lut_l%, lut_m%, regs%,
/// bram%, dsp%).
pub const PAPER_TABLE6: &[(&str, f64, f64, f64, f64, f64, f64, f64, f64)] = &[
    ("O", 527.9, 0.0, 5.02, 5.35, 2.22, 6.38, 34.0, 0.14),
    ("DP", 520.2, 674.7, 3.36, 5.45, 2.29, 6.67, 32.0, 0.21),
];

/// The CL0 request for FW: a tiny deeply-pipelined design closes far
/// above the shell default (Table 6: 527.9 MHz achieved).
pub const CL0_REQUEST_MHZ: f64 = 540.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_with_repeat() {
        let g = build();
        crate::ir::validate::validate(&g).unwrap();
        assert!(g.repeat.is_some());
        let env = g.bind(&[("N", 16)]).unwrap();
        assert_eq!(g.repeat.as_ref().unwrap().range.count(&env), Some(16));
    }

    #[test]
    fn reference_shortens_paths() {
        let n = 16;
        let d = random_graph(n, 7, 0.3);
        let r = reference(&d, n);
        // no path got longer; triangle inequality holds
        for i in 0..n * n {
            assert!(r[i] <= d[i]);
        }
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    assert!(r[i * n + j] <= r[i * n + k] + r[k * n + j] + 1e-2);
                }
            }
        }
    }

    #[test]
    fn paper_speedup_is_half_again() {
        let (o, dp) = (&PAPER_TABLE6[0], &PAPER_TABLE6[1]);
        assert!((o.3 / dp.3 - 1.49).abs() < 0.02);
    }
}
