//! Iterative 3-D stencil chains (paper §4.3, Tables 4 & 5):
//! StencilFlow-style linear chains of S stages over a
//! 2¹⁶ × 32 × 32 domain, 8-way vectorized for Jacobi (lower intensity)
//! and 4-way for Diffusion.

use crate::ir::{DType, GraphBuilder, LibraryOp, Memlet, Sdfg, StencilKind, VecType};
use crate::symbolic::{Expr, Range, Subset};

/// Paper domain: 2¹⁶ × 32 × 32 points (§4.3).
pub const PAPER_NX: i64 = 1 << 16;
pub const PAPER_NY: i64 = 32;
pub const PAPER_NZ: i64 = 32;

/// Verification-scale domain matching the AOT artifact (32³, S=4).
pub const GOLDEN_NX: i64 = 32;
pub const GOLDEN_STAGES: usize = 4;

/// Vectorization widths used by the paper per stencil kind.
pub fn paper_vec_width(kind: StencilKind) -> usize {
    match kind {
        StencilKind::Jacobi3D => 8,
        StencilKind::Diffusion3D => 4,
    }
}

/// Build a chain of `stages` stencil stages. Stage i reads from the
/// previous stage's output through a transient array (fused to a
/// stream by the streaming transformation — each stage is its own
/// kernel, as in the paper).
pub fn build(kind: StencilKind, stages: usize, vec_width: usize) -> Sdfg {
    assert!(stages >= 1);
    let mut b = GraphBuilder::new(&format!("{}_s{stages}", kind.name()));
    let vt = VecType::of(DType::F32, vec_width);
    let shape = || vec![Expr::sym("NX"), Expr::sym("NY"), Expr::sym("NZ_v")];
    // NZ_v: innermost dimension in vector units
    b.array("v_in", vt, shape());
    b.array("v_out", vt, shape());
    let full = Subset::new(vec![
        Range::upto_sym("NX"),
        Range::upto_sym("NY"),
        Range::upto_sym("NZ_v"),
    ]);

    let mut prev = b.access("v_in");
    let mut prev_name = "v_in".to_string();
    for s in 0..stages {
        let lib = b.library(
            &format!("{}_stage{s}", kind.name()),
            LibraryOp::StencilStage { kind, vec_width },
        );
        b.edge(prev, lib, Memlet::new(&prev_name, full.clone()).with_dst("in"));
        if s + 1 == stages {
            let out = b.access("v_out");
            b.edge(lib, out, Memlet::new("v_out", full.clone()).with_src("out"));
        } else {
            let tname = format!("tmp{s}");
            b.bram(&tname, vt, shape());
            // transient chained buffer — becomes an inter-kernel stream
            let t = b.access(&tname);
            b.edge(lib, t, Memlet::new(&tname, full.clone()).with_src("out"));
            prev = t;
            prev_name = tname;
        }
    }
    let mut g = b.finish();
    // transient chain buffers live between kernels; mark them HBM-free
    g.add_symbol("NZ_v");
    g
}

/// Flops per full chain run (ops per output point × points × stages).
pub fn flops(kind: StencilKind, nx: i64, ny: i64, nz: i64, stages: usize) -> f64 {
    let per_point = {
        let ops = crate::codegen::lower::stencil_ops(kind);
        (ops.adds + ops.muls + ops.divs + ops.minmax) as f64
    };
    per_point * (nx * ny * nz) as f64 * stages as f64
}

/// Paper Table 4 (Jacobi): (S, O/DP, CL0, CL1, GOp/s, lut_l%, lut_m%,
/// regs%, bram%, dsp%, mops_per_dsp).
pub const PAPER_TABLE4: &[(usize, &str, f64, f64, f64, f64, f64, f64, f64, f64, f64)] = &[
    (8, "O", 307.6, 0.0, 101.4, 20.25, 6.21, 22.48, 15.33, 28.89, 121.9),
    (8, "DP", 322.4, 510.4, 96.9, 14.2, 6.89, 19.14, 10.57, 14.44, 232.8),
    (16, "O", 304.2, 0.0, 202.5, 36.15, 10.58, 39.21, 24.85, 57.78, 121.7),
    (16, "DP", 331.5, 478.0, 180.7, 23.37, 12.01, 32.5, 15.33, 28.89, 217.1),
    (40, "O", 305.0, 0.0, 245.3, 42.17, 12.71, 49.2, 30.11, 72.22, 117.9),
    (40, "DP", 258.0, 460.8, 414.8, 47.78, 26.1, 64.5, 23.41, 72.22, 199.0),
];

/// Paper Table 5 (Diffusion).
pub const PAPER_TABLE5: &[(usize, &str, f64, f64, f64, f64, f64, f64, f64, f64, f64)] = &[
    (8, "O", 309.1, 0.0, 110.4, 16.55, 4.85, 18.25, 10.57, 31.67, 121.0),
    (8, "DP", 329.4, 537.3, 102.8, 12.08, 5.27, 15.88, 8.18, 16.67, 214.2),
    (16, "O", 311.4, 0.0, 220.6, 28.52, 7.91, 30.96, 15.33, 63.33, 121.0),
    (16, "DP", 333.1, 490.4, 202.6, 19.42, 8.8, 25.94, 10.57, 33.33, 211.1),
    (20, "O", 305.0, 0.0, 275.7, 34.57, 9.44, 37.27, 17.71, 79.17, 120.9),
    (40, "DP", 255.2, 462.9, 460.3, 40.66, 19.38, 56.12, 17.71, 83.33, 191.8),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_builds_and_validates() {
        for stages in [1, 4, 8] {
            let g = build(StencilKind::Jacobi3D, stages, 8);
            crate::ir::validate::validate(&g).unwrap();
            let libs = g
                .node_ids()
                .filter(|i| matches!(g.node(*i), crate::ir::Node::Library { .. }))
                .count();
            assert_eq!(libs, stages);
        }
    }

    #[test]
    fn paper_dp_halves_dsp_at_fixed_stages() {
        // Table 4, S=8 and S=16
        assert!((PAPER_TABLE4[1].9 / PAPER_TABLE4[0].9 - 0.5).abs() < 0.01);
        assert!((PAPER_TABLE4[3].9 / PAPER_TABLE4[2].9 - 0.5).abs() < 0.01);
    }

    #[test]
    fn paper_dsp_efficiency_doubles() {
        for t in [PAPER_TABLE4, PAPER_TABLE5] {
            let gain = t[1].10 / t[0].10;
            assert!(gain > 1.5, "MOp/s/DSP gain {gain}");
        }
    }

    #[test]
    fn flops_scale_with_stages() {
        let f8 = flops(StencilKind::Jacobi3D, 64, 32, 32, 8);
        let f16 = flops(StencilKind::Jacobi3D, 64, 32, 32, 16);
        assert!((f16 / f8 - 2.0).abs() < 1e-12);
    }
}
