//! Matrix multiplication (paper §4.2, Table 3): the communication-
//! avoiding systolic GEMM of de Fine Licht et al. [10], in DaCe form
//! (the paper's "O" column), plus the hand-written-HLS baseline model
//! ("CA" column).

use crate::ir::{GraphBuilder, LibraryOp, Memlet, Sdfg, VecType};
use crate::symbolic::{Expr, Range, Subset};

/// Paper configuration: PE vectorization width fixed at 16 (§4.2).
pub const VEC_WIDTH: usize = 16;

/// Memory tile sizes (calibrated so 32 PEs fill ≈80 % of SLR BRAM as
/// in Table 3; DESIGN.md §8).
pub const TILE_M: usize = 128;
pub const TILE_N: usize = 64;

/// Paper-scale problem (square); reproduces Table 3's GOp/s range at
/// the reported clocks.
pub const PAPER_NMK: i64 = 4096;

/// Verification-scale size matching the AOT artifact.
pub const GOLDEN_NMK: i64 = 128;

/// Build the GEMM SDFG around the systolic library node.
pub fn build(pes: usize) -> Sdfg {
    // arrays are stored vectorized (512-bit interface words, as the CA
    // implementation does): shapes count 16-lane vectors in the
    // innermost dimension, with K_v = K/16 and M_v = M/16 bindings
    let mut b = GraphBuilder::new(&format!("gemm_p{pes}"));
    let vt = VecType::of(crate::ir::DType::F32, VEC_WIDTH);
    b.array("A", vt, vec![Expr::sym("N"), Expr::sym("K_v")]);
    b.array("B", vt, vec![Expr::sym("K"), Expr::sym("M_v")]);
    b.array("C", vt, vec![Expr::sym("N"), Expr::sym("M_v")]);
    let a = b.access("A");
    let bb = b.access("B");
    let c = b.access("C");
    let lib = b.library(
        &format!("systolic_p{pes}"),
        LibraryOp::SystolicGemm { pes, vec_width: VEC_WIDTH, tile_m: TILE_M, tile_n: TILE_N },
    );
    let full = |rows: &str, cols: &str| {
        Subset::new(vec![Range::upto_sym(rows), Range::upto_sym(cols)])
    };
    b.edge(a, lib, Memlet::new("A", full("N", "K_v")).with_dst("a"));
    b.edge(bb, lib, Memlet::new("B", full("K", "M_v")).with_dst("b"));
    b.edge(lib, c, Memlet::new("C", full("N", "M_v")).with_src("c"));
    b.finish()
}

/// Standard bindings for an N×N×N problem.
pub fn bindings(n: i64) -> Vec<(String, i64)> {
    assert_eq!(n % VEC_WIDTH as i64, 0);
    vec![
        ("N".into(), n),
        ("M".into(), n),
        ("K".into(), n),
        ("K_v".into(), n / VEC_WIDTH as i64),
        ("M_v".into(), n / VEC_WIDTH as i64),
    ]
}

/// Flops: 2·N·M·K.
pub fn flops(n: i64, m: i64, k: i64) -> f64 {
    2.0 * n as f64 * m as f64 * k as f64
}

/// Paper Table 3: (label, pes, CL0, CL1, GOp/s, lut_logic%, lut_mem%,
/// regs%, bram%, dsp%, mops_per_dsp).
pub const PAPER_TABLE3: &[(&str, usize, f64, f64, f64, f64, f64, f64, f64, f64, f64)] = &[
    ("CA", 32, 250.0, 0.0, 253.2, 43.9, 6.9, 44.5, 81.4, 88.9, 98.9),
    ("O", 32, 268.0, 0.0, 256.1, 44.8, 13.0, 44.3, 80.3, 90.0, 98.8),
    ("DP", 32, 261.4, 452.8, 219.1, 32.1, 10.1, 36.6, 47.0, 45.6, 167.0),
    ("DP", 48, 269.9, 398.2, 260.8, 41.3, 14.8, 45.9, 63.6, 67.9, 133.5),
    ("DP", 64, 252.9, 322.5, 293.8, 53.7, 17.4, 60.1, 82.7, 90.0, 113.3),
];

/// The hand-written HLS baseline [10] as a design model: identical
/// netlist shape (the DaCe implementation "performs on par" with it —
/// §4.2), with the baseline's slightly leaner LUT-memory budget (no
/// DaCe-generated inter-module glue) and its 250 MHz clock request.
pub fn ca_baseline(pes: usize) -> Sdfg {
    let mut g = build(pes);
    g.name = format!("gemm_ca_p{pes}");
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_validates() {
        crate::ir::validate::validate(&build(32)).unwrap();
    }

    #[test]
    fn paper_dp_halves_dsp_at_same_pes() {
        let o = PAPER_TABLE3[1];
        let dp = PAPER_TABLE3[2];
        assert!((dp.9 / o.9 - 0.5).abs() < 0.02);
        // BRAM cut to ~58 %
        assert!((dp.8 / o.8 - 0.585).abs() < 0.02);
    }

    #[test]
    fn dp64_beats_handwritten_by_15_percent() {
        let ca = PAPER_TABLE3[0].4;
        let dp64 = PAPER_TABLE3[4].4;
        assert!((dp64 / ca - 1.16).abs() < 0.02);
    }
}
