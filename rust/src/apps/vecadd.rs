//! Vector addition (paper §4.1, Table 2).

use crate::ir::builder::vecadd_sdfg;
use crate::ir::Sdfg;

/// Problem size of the paper-scale run. The paper does not state N;
/// 2²⁶ elements reproduce the ~0.1 s runtimes of Table 2 at the
/// reported clocks (DESIGN.md §8).
pub const PAPER_N: i64 = 1 << 26;

/// Verification-scale size matching the AOT artifact.
pub const GOLDEN_N: i64 = 4096;

/// Build the vecadd SDFG (scalar; vectorization applied as a pass).
pub fn build() -> Sdfg {
    vecadd_sdfg(1)
}

/// Flops of one run: N adds.
pub fn flops(n: i64) -> f64 {
    n as f64
}

/// Paper Table 2 reference rows: (vect width, O/DP, CL0, CL1, time_s,
/// lut_logic%, lut_mem%, regs%, bram%, dsp%).
pub const PAPER_TABLE2: &[(usize, &str, f64, f64, f64, f64, f64, f64, f64, f64)] = &[
    (2, "O", 339.4, 0.0, 0.1112, 5.27, 2.27, 6.74, 6.77, 0.14),
    (2, "DP", 340.0, 668.4, 0.1111, 5.37, 2.26, 6.95, 6.77, 0.07),
    (4, "O", 332.5, 0.0, 0.0557, 5.39, 2.34, 6.86, 6.92, 0.28),
    (4, "DP", 343.2, 651.4, 0.0557, 5.46, 2.33, 7.16, 6.92, 0.14),
    (8, "O", 344.5, 0.0, 0.0281, 5.57, 2.48, 7.05, 7.22, 0.56),
    (8, "DP", 335.2, 643.9, 0.0280, 5.65, 2.47, 7.57, 7.22, 0.28),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_validates() {
        crate::ir::validate::validate(&build()).unwrap();
    }

    #[test]
    fn paper_rows_have_halved_dsp() {
        for pair in PAPER_TABLE2.chunks(2) {
            let (o, dp) = (&pair[0], &pair[1]);
            assert_eq!(o.0, dp.0);
            assert!((dp.9 - o.9 / 2.0).abs() < 1e-9, "width {}", o.0);
        }
    }
}
