//! The four applications of the paper's evaluation (§4), each as an IR
//! builder plus its workload parameters, flop accounting and the
//! paper's reference numbers (used by EXPERIMENTS.md comparisons).

pub mod floyd_warshall;
pub mod matmul;
pub mod stencil;
pub mod vecadd;
