//! Frontend AST.

/// Scalar expression in the surface language.
#[derive(Clone, Debug, PartialEq)]
pub enum SExpr {
    Num(f32),
    /// `name[index...]` — array element reference.
    Ref { array: String, indices: Vec<IExpr> },
    Bin(char, Box<SExpr>, Box<SExpr>),
    /// `min(a, b)` / `max(a, b)` / `abs(a)`
    Call(String, Vec<SExpr>),
}

/// Integer index expression (must lower to affine form).
#[derive(Clone, Debug, PartialEq)]
pub enum IExpr {
    Num(i64),
    Sym(String),
    Add(Box<IExpr>, Box<IExpr>),
    Sub(Box<IExpr>, Box<IExpr>),
    Mul(Box<IExpr>, Box<IExpr>),
}

/// `map i in lo:hi:` statement with a single assignment body.
#[derive(Clone, Debug)]
pub struct MapStmt {
    pub param: String,
    pub lo: IExpr,
    pub hi: IExpr,
    /// `target[idx...] = expr`
    pub target: (String, Vec<IExpr>),
    pub value: SExpr,
    /// true when declared `for` instead of `map` (sequential/dependent).
    pub sequential: bool,
}

/// Array declaration `name: f32[dims] @ hbm`.
#[derive(Clone, Debug)]
pub struct ArrayDecl {
    pub name: String,
    pub dims: Vec<IExpr>,
}

/// A full program.
#[derive(Clone, Debug)]
pub struct Program {
    pub name: String,
    pub symbols: Vec<String>,
    pub arrays: Vec<ArrayDecl>,
    pub maps: Vec<MapStmt>,
}
