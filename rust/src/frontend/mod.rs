//! Tiny high-level frontend.
//!
//! The paper's programs are written in Python and symbolically analyzed
//! into the DaCe IR. We provide the same entry point as a small textual
//! DSL: programs declare symbolic-size arrays and write `map` loops with
//! element-wise expressions; the lowering produces the exact SDFG shape
//! the transformations expect. Example (the paper's running example):
//!
//! ```text
//! program vecadd(N):
//!   x: f32[N] @ hbm
//!   y: f32[N] @ hbm
//!   z: f32[N] @ hbm
//!   map i in 0:N:
//!     z[i] = x[i] + y[i]
//! ```

pub mod ast;
pub mod lexer;
pub mod lower;
pub mod parser;

pub use lower::lower;
pub use parser::parse;

use crate::ir::Sdfg;

/// Parse + lower in one step.
pub fn compile(source: &str) -> Result<Sdfg, String> {
    let prog = parse(source)?;
    lower(&prog)
}
