//! Recursive-descent parser for the frontend DSL.

use super::ast::{ArrayDecl, IExpr, MapStmt, Program, SExpr};
use super::lexer::{lex, Tok};

struct P {
    toks: Vec<Tok>,
    pos: usize,
}

impl P {
    fn peek(&self) -> &Tok {
        self.toks.get(self.pos).unwrap_or(&Tok::Eof)
    }

    fn next(&mut self) -> Tok {
        let t = self.peek().clone();
        self.pos += 1;
        t
    }

    fn expect(&mut self, t: &Tok) -> Result<(), String> {
        let got = self.next();
        if &got == t {
            Ok(())
        } else {
            Err(format!("expected {t:?}, got {got:?}"))
        }
    }

    fn ident(&mut self) -> Result<String, String> {
        match self.next() {
            Tok::Ident(s) => Ok(s),
            other => Err(format!("expected identifier, got {other:?}")),
        }
    }

    fn skip_newlines(&mut self) {
        while matches!(self.peek(), Tok::Newline) {
            self.pos += 1;
        }
    }

    // ---- integer index expressions: term (+|-) term ----
    fn iexpr(&mut self) -> Result<IExpr, String> {
        let mut lhs = self.iterm()?;
        loop {
            match self.peek() {
                Tok::Plus => {
                    self.pos += 1;
                    lhs = IExpr::Add(Box::new(lhs), Box::new(self.iterm()?));
                }
                Tok::Minus => {
                    self.pos += 1;
                    lhs = IExpr::Sub(Box::new(lhs), Box::new(self.iterm()?));
                }
                _ => return Ok(lhs),
            }
        }
    }

    fn iterm(&mut self) -> Result<IExpr, String> {
        let mut lhs = self.iatom()?;
        while matches!(self.peek(), Tok::Star) {
            self.pos += 1;
            lhs = IExpr::Mul(Box::new(lhs), Box::new(self.iatom()?));
        }
        Ok(lhs)
    }

    fn iatom(&mut self) -> Result<IExpr, String> {
        match self.next() {
            Tok::Int(v) => Ok(IExpr::Num(v)),
            Tok::Ident(s) => Ok(IExpr::Sym(s)),
            Tok::Minus => Ok(IExpr::Sub(Box::new(IExpr::Num(0)), Box::new(self.iatom()?))),
            Tok::LParen => {
                let e = self.iexpr()?;
                self.expect(&Tok::RParen)?;
                Ok(e)
            }
            other => Err(format!("expected index expression, got {other:?}")),
        }
    }

    // ---- scalar expressions ----
    fn sexpr(&mut self) -> Result<SExpr, String> {
        let mut lhs = self.sterm()?;
        loop {
            match self.peek() {
                Tok::Plus => {
                    self.pos += 1;
                    lhs = SExpr::Bin('+', Box::new(lhs), Box::new(self.sterm()?));
                }
                Tok::Minus => {
                    self.pos += 1;
                    lhs = SExpr::Bin('-', Box::new(lhs), Box::new(self.sterm()?));
                }
                _ => return Ok(lhs),
            }
        }
    }

    fn sterm(&mut self) -> Result<SExpr, String> {
        let mut lhs = self.satom()?;
        loop {
            match self.peek() {
                Tok::Star => {
                    self.pos += 1;
                    lhs = SExpr::Bin('*', Box::new(lhs), Box::new(self.satom()?));
                }
                Tok::Slash => {
                    self.pos += 1;
                    lhs = SExpr::Bin('/', Box::new(lhs), Box::new(self.satom()?));
                }
                _ => return Ok(lhs),
            }
        }
    }

    fn satom(&mut self) -> Result<SExpr, String> {
        match self.next() {
            Tok::Float(v) => Ok(SExpr::Num(v)),
            Tok::Int(v) => Ok(SExpr::Num(v as f32)),
            Tok::Minus => {
                let a = self.satom()?;
                Ok(SExpr::Bin('-', Box::new(SExpr::Num(0.0)), Box::new(a)))
            }
            Tok::LParen => {
                let e = self.sexpr()?;
                self.expect(&Tok::RParen)?;
                Ok(e)
            }
            Tok::Ident(name) => match self.peek() {
                Tok::LBracket => {
                    self.pos += 1;
                    let mut indices = vec![self.iexpr()?];
                    while matches!(self.peek(), Tok::Comma) {
                        self.pos += 1;
                        indices.push(self.iexpr()?);
                    }
                    self.expect(&Tok::RBracket)?;
                    Ok(SExpr::Ref { array: name, indices })
                }
                Tok::LParen => {
                    self.pos += 1;
                    let mut args = vec![self.sexpr()?];
                    while matches!(self.peek(), Tok::Comma) {
                        self.pos += 1;
                        args.push(self.sexpr()?);
                    }
                    self.expect(&Tok::RParen)?;
                    Ok(SExpr::Call(name, args))
                }
                _ => Err(format!("bare identifier '{name}' in scalar expression (arrays need [index])")),
            },
            other => Err(format!("expected scalar expression, got {other:?}")),
        }
    }

    fn array_decl(&mut self, name: String) -> Result<ArrayDecl, String> {
        // name ':' f32 '[' dims ']' '@' hbm
        let ty = self.ident()?;
        if ty != "f32" {
            return Err(format!("only f32 arrays supported, got '{ty}'"));
        }
        self.expect(&Tok::LBracket)?;
        let mut dims = vec![self.iexpr()?];
        while matches!(self.peek(), Tok::Comma) {
            self.pos += 1;
            dims.push(self.iexpr()?);
        }
        self.expect(&Tok::RBracket)?;
        self.expect(&Tok::At)?;
        let loc = self.ident()?;
        if loc != "hbm" {
            return Err(format!("only '@ hbm' storage supported in the DSL, got '{loc}'"));
        }
        Ok(ArrayDecl { name, dims })
    }

    fn map_stmt(&mut self, sequential: bool) -> Result<MapStmt, String> {
        // (map|for) i in lo:hi ':' NEWLINE INDENT target[idx] '=' expr
        let param = self.ident()?;
        let kw = self.ident()?;
        if kw != "in" {
            return Err(format!("expected 'in', got '{kw}'"));
        }
        let lo = self.iexpr()?;
        self.expect(&Tok::Colon)?;
        let hi = self.iexpr()?;
        self.expect(&Tok::Colon)?;
        self.skip_newlines();
        self.expect(&Tok::Indent)?;
        let target_name = self.ident()?;
        self.expect(&Tok::LBracket)?;
        let mut tidx = vec![self.iexpr()?];
        while matches!(self.peek(), Tok::Comma) {
            self.pos += 1;
            tidx.push(self.iexpr()?);
        }
        self.expect(&Tok::RBracket)?;
        self.expect(&Tok::Assign)?;
        let value = self.sexpr()?;
        Ok(MapStmt { param, lo, hi, target: (target_name, tidx), value, sequential })
    }
}

/// Parse DSL source into a [`Program`].
pub fn parse(source: &str) -> Result<Program, String> {
    let toks = lex(source)?;
    let mut p = P { toks, pos: 0 };
    p.skip_newlines();

    // header
    let kw = p.ident()?;
    if kw != "program" {
        return Err(format!("expected 'program', got '{kw}'"));
    }
    let name = p.ident()?;
    let mut symbols = Vec::new();
    p.expect(&Tok::LParen)?;
    if !matches!(p.peek(), Tok::RParen) {
        symbols.push(p.ident()?);
        while matches!(p.peek(), Tok::Comma) {
            p.pos += 1;
            symbols.push(p.ident()?);
        }
    }
    p.expect(&Tok::RParen)?;
    p.expect(&Tok::Colon)?;
    p.skip_newlines();

    let mut arrays = Vec::new();
    let mut maps = Vec::new();
    loop {
        p.skip_newlines();
        // body lines are indented
        while matches!(p.peek(), Tok::Indent) {
            p.pos += 1;
        }
        match p.next() {
            Tok::Eof => break,
            Tok::Ident(word) if word == "map" => maps.push(p.map_stmt(false)?),
            Tok::Ident(word) if word == "for" => maps.push(p.map_stmt(true)?),
            Tok::Ident(name) => {
                p.expect(&Tok::Colon)?;
                arrays.push(p.array_decl(name)?);
            }
            other => return Err(format!("unexpected token {other:?} at top level")),
        }
    }
    if maps.is_empty() {
        return Err("program has no map statement".into());
    }
    Ok(Program { name, symbols, arrays, maps })
}

#[cfg(test)]
mod tests {
    use super::*;

    const VECADD: &str = "
program vecadd(N):
  x: f32[N] @ hbm
  y: f32[N] @ hbm
  z: f32[N] @ hbm
  map i in 0:N:
    z[i] = x[i] + y[i]
";

    #[test]
    fn parses_vecadd() {
        let prog = parse(VECADD).unwrap();
        assert_eq!(prog.name, "vecadd");
        assert_eq!(prog.symbols, vec!["N"]);
        assert_eq!(prog.arrays.len(), 3);
        assert_eq!(prog.maps.len(), 1);
        let m = &prog.maps[0];
        assert_eq!(m.param, "i");
        assert!(!m.sequential);
        assert_eq!(m.target.0, "z");
        match &m.value {
            SExpr::Bin('+', a, b) => {
                assert!(matches!(**a, SExpr::Ref { .. }));
                assert!(matches!(**b, SExpr::Ref { .. }));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parses_scaled_indices_and_calls() {
        let src = "
program saxpy(N):
  x: f32[N] @ hbm
  y: f32[N] @ hbm
  map i in 0:N:
    y[i] = min(2 * x[2*i+1], y[i])
";
        let prog = parse(src).unwrap();
        match &prog.maps[0].value {
            SExpr::Call(f, args) => {
                assert_eq!(f, "min");
                assert_eq!(args.len(), 2);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn for_is_sequential() {
        let src = "
program scan(N):
  x: f32[N] @ hbm
  for i in 1:N:
    x[i] = x[i] + x[i-1]
";
        let prog = parse(src).unwrap();
        assert!(prog.maps[0].sequential);
    }

    #[test]
    fn error_on_missing_map() {
        let src = "\nprogram nothing(N):\n  x: f32[N] @ hbm\n";
        assert!(parse(src).unwrap_err().contains("no map"));
    }

    #[test]
    fn error_on_bad_type() {
        let src = "\nprogram p(N):\n  x: f64[N] @ hbm\n  map i in 0:N:\n    x[i] = x[i]\n";
        assert!(parse(src).unwrap_err().contains("f32"));
    }
}
