//! Lowering from the frontend AST to the SDFG IR.
//!
//! Mirrors DaCe's Python-frontend behaviour at small scale: each array
//! becomes an HBM container, each `map` becomes a pipelined map scope
//! with one tasklet, each distinct array reference becomes an input
//! connector fed by a memlet with the symbolic subset of the reference.

use std::collections::BTreeMap;

use super::ast::{IExpr, Program, SExpr};
use crate::ir::{GraphBuilder, MapSchedule, Memlet, TaskExpr, Tasklet};
use crate::symbolic::{Expr, Range, Subset};

fn lower_iexpr(e: &IExpr) -> Expr {
    match e {
        IExpr::Num(v) => Expr::int(*v),
        IExpr::Sym(s) => Expr::sym(s),
        IExpr::Add(a, b) => lower_iexpr(a).add(&lower_iexpr(b)),
        IExpr::Sub(a, b) => lower_iexpr(a).sub(&lower_iexpr(b)),
        IExpr::Mul(a, b) => lower_iexpr(a).mul(&lower_iexpr(b)),
    }
}

/// Collect array references; assign each distinct (array, subset) a
/// connector name, and rewrite the expression over connectors.
fn lower_sexpr(
    e: &SExpr,
    refs: &mut Vec<(String, Subset)>,
    conns: &mut BTreeMap<String, String>,
) -> Result<TaskExpr, String> {
    Ok(match e {
        SExpr::Num(v) => TaskExpr::Const(*v),
        SExpr::Ref { array, indices } => {
            let subset = Subset::indices(indices.iter().map(lower_iexpr).collect());
            let key = format!("{array}{subset}");
            let conn = conns.entry(key).or_insert_with(|| {
                let c = format!("in{}", refs.len());
                refs.push((array.clone(), subset.clone()));
                c
            });
            TaskExpr::input(conn)
        }
        SExpr::Bin(op, a, b) => {
            let x = lower_sexpr(a, refs, conns)?;
            let y = lower_sexpr(b, refs, conns)?;
            match op {
                '+' => x.add(y),
                '-' => x.sub(y),
                '*' => x.mul(y),
                '/' => TaskExpr::Bin(crate::ir::BinOp::Div, Box::new(x), Box::new(y)),
                other => return Err(format!("unknown operator '{other}'")),
            }
        }
        SExpr::Call(f, args) => {
            let mut lowered: Vec<TaskExpr> = args
                .iter()
                .map(|a| lower_sexpr(a, refs, conns))
                .collect::<Result<_, _>>()?;
            match (f.as_str(), lowered.len()) {
                ("min", 2) => {
                    let b = lowered.pop().unwrap();
                    lowered.pop().unwrap().min(b)
                }
                ("max", 2) => {
                    let b = lowered.pop().unwrap();
                    lowered.pop().unwrap().max(b)
                }
                ("abs", 1) => TaskExpr::Un(crate::ir::UnOp::Abs, Box::new(lowered.pop().unwrap())),
                (other, n) => return Err(format!("unknown function {other}/{n}")),
            }
        }
    })
}

/// Lower a parsed program to an SDFG.
pub fn lower(prog: &Program) -> Result<crate::ir::Sdfg, String> {
    let mut b = GraphBuilder::new(&prog.name);
    for a in &prog.arrays {
        b.array_f32(&a.name, a.dims.iter().map(lower_iexpr).collect());
    }

    for (mi, m) in prog.maps.iter().enumerate() {
        let lo = lower_iexpr(&m.lo);
        let hi = lower_iexpr(&m.hi);
        let range = Range::new(lo, hi, 1);
        let schedule = if m.sequential { MapSchedule::Sequential } else { MapSchedule::Pipeline };
        let (me, mx) = b.map(&format!("map{mi}"), &[&m.param], vec![range], schedule);

        let mut refs = Vec::new();
        let mut conns = BTreeMap::new();
        let expr = lower_sexpr(&m.value, &mut refs, &mut conns)?;
        let t = b.tasklet(Tasklet::new(&format!("{}_body", prog.name), vec![("out", expr)]));

        // inputs: access → entry → tasklet
        for (i, (array, subset)) in refs.iter().enumerate() {
            let acc = b.access(array);
            let decl = b
                .graph()
                .container(array)
                .ok_or_else(|| format!("unknown array '{array}'"))?;
            let full = Subset::new(
                decl.shape.iter().map(|d| Range::new(Expr::int(0), d.clone(), 1)).collect(),
            );
            b.edge(acc, me, Memlet::new(array, full));
            b.edge(me, t, Memlet::new(array, subset.clone()).with_dst(&format!("in{i}")));
        }

        // output: tasklet → exit → access
        let (tname, tidx) = &m.target;
        let tacc = b.access(tname);
        let tdecl = b
            .graph()
            .container(tname)
            .ok_or_else(|| format!("unknown target array '{tname}'"))?;
        let tfull = Subset::new(
            tdecl.shape.iter().map(|d| Range::new(Expr::int(0), d.clone(), 1)).collect(),
        );
        let tsubset = Subset::indices(tidx.iter().map(lower_iexpr).collect());
        b.edge(t, mx, Memlet::new(tname, tsubset).with_src("out"));
        b.edge(mx, tacc, Memlet::new(tname, tfull));
    }

    let g = b.finish();
    crate::ir::validate::validate(&g).map_err(|e| e.to_string())?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse;
    use crate::ir::Node;

    const VECADD: &str = "
program vecadd(N):
  x: f32[N] @ hbm
  y: f32[N] @ hbm
  z: f32[N] @ hbm
  map i in 0:N:
    z[i] = x[i] + y[i]
";

    #[test]
    fn vecadd_lowering_matches_builder_shape() {
        let g = lower(&parse(VECADD).unwrap()).unwrap();
        // same node census as ir::builder::vecadd_sdfg
        let access = g.node_ids().filter(|i| g.node(*i).is_access()).count();
        let tasklets = g
            .node_ids()
            .filter(|i| matches!(g.node(*i), Node::Tasklet(_)))
            .count();
        assert_eq!(access, 3);
        assert_eq!(tasklets, 1);
        assert!(g.topo_order().is_ok());
    }

    #[test]
    fn repeated_ref_shares_connector() {
        let src = "
program sq(N):
  x: f32[N] @ hbm
  y: f32[N] @ hbm
  map i in 0:N:
    y[i] = x[i] * x[i]
";
        let g = lower(&parse(src).unwrap()).unwrap();
        // only one input edge into the tasklet for x[i]
        let t = g
            .node_ids()
            .find(|i| matches!(g.node(*i), Node::Tasklet(_)))
            .unwrap();
        assert_eq!(g.in_edges(t).len(), 1);
    }

    #[test]
    fn affine_indices_lower_exactly() {
        let src = "
program gather(N):
  x: f32[N] @ hbm
  y: f32[N] @ hbm
  map i in 0:N:
    y[i] = x[2*i+1]
";
        let g = lower(&parse(src).unwrap()).unwrap();
        let t = g
            .node_ids()
            .find(|i| matches!(g.node(*i), Node::Tasklet(_)))
            .unwrap();
        let e = g.in_edges(t)[0];
        let sub = &g.edge(e).memlet.subset;
        assert_eq!(
            sub.dims[0].begin,
            Expr::sym("i").scale(2).add(&Expr::int(1))
        );
    }

    #[test]
    fn stencil_1d_neighbours() {
        let src = "
program smooth(N):
  a: f32[N] @ hbm
  b: f32[N] @ hbm
  map i in 1:N-1:
    b[i] = 0.25 * a[i-1] + 0.5 * a[i] + 0.25 * a[i+1]
";
        let g = lower(&parse(src).unwrap()).unwrap();
        let t = g
            .node_ids()
            .find(|i| matches!(g.node(*i), Node::Tasklet(_)))
            .unwrap();
        assert_eq!(g.in_edges(t).len(), 3); // three distinct neighbours
    }
}
