//! Hand-rolled lexer for the frontend DSL.

#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    Ident(String),
    Int(i64),
    Float(f32),
    // punctuation
    LParen,
    RParen,
    LBracket,
    RBracket,
    Colon,
    Comma,
    At,
    Assign,
    Plus,
    Minus,
    Star,
    Slash,
    Newline,
    Indent,
    Eof,
}

/// Tokenize; indentation is significant only as "line starts with
/// whitespace" (the grammar has one nesting level).
pub fn lex(src: &str) -> Result<Vec<Tok>, String> {
    let mut toks = Vec::new();
    for raw_line in src.lines() {
        let line = raw_line.split('#').next().unwrap_or("");
        if line.trim().is_empty() {
            continue;
        }
        if line.starts_with(' ') || line.starts_with('\t') {
            toks.push(Tok::Indent);
        }
        let mut chars = line.trim().chars().peekable();
        while let Some(&c) = chars.peek() {
            match c {
                ' ' | '\t' => {
                    chars.next();
                }
                '(' => {
                    chars.next();
                    toks.push(Tok::LParen);
                }
                ')' => {
                    chars.next();
                    toks.push(Tok::RParen);
                }
                '[' => {
                    chars.next();
                    toks.push(Tok::LBracket);
                }
                ']' => {
                    chars.next();
                    toks.push(Tok::RBracket);
                }
                ':' => {
                    chars.next();
                    toks.push(Tok::Colon);
                }
                ',' => {
                    chars.next();
                    toks.push(Tok::Comma);
                }
                '@' => {
                    chars.next();
                    toks.push(Tok::At);
                }
                '=' => {
                    chars.next();
                    toks.push(Tok::Assign);
                }
                '+' => {
                    chars.next();
                    toks.push(Tok::Plus);
                }
                '-' => {
                    chars.next();
                    toks.push(Tok::Minus);
                }
                '*' => {
                    chars.next();
                    toks.push(Tok::Star);
                }
                '/' => {
                    chars.next();
                    toks.push(Tok::Slash);
                }
                c if c.is_ascii_digit() => {
                    let mut s = String::new();
                    let mut is_float = false;
                    while let Some(&d) = chars.peek() {
                        if d.is_ascii_digit() {
                            s.push(d);
                            chars.next();
                        } else if d == '.' && !is_float {
                            is_float = true;
                            s.push(d);
                            chars.next();
                        } else {
                            break;
                        }
                    }
                    if is_float {
                        toks.push(Tok::Float(s.parse().map_err(|e| format!("bad float {s}: {e}"))?));
                    } else {
                        toks.push(Tok::Int(s.parse().map_err(|e| format!("bad int {s}: {e}"))?));
                    }
                }
                c if c.is_ascii_alphabetic() || c == '_' => {
                    let mut s = String::new();
                    while let Some(&d) = chars.peek() {
                        if d.is_ascii_alphanumeric() || d == '_' {
                            s.push(d);
                            chars.next();
                        } else {
                            break;
                        }
                    }
                    toks.push(Tok::Ident(s));
                }
                other => return Err(format!("unexpected character '{other}'")),
            }
        }
        toks.push(Tok::Newline);
    }
    toks.push(Tok::Eof);
    Ok(toks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexes_program_header() {
        let t = lex("program vecadd(N):").unwrap();
        assert_eq!(
            t,
            vec![
                Tok::Ident("program".into()),
                Tok::Ident("vecadd".into()),
                Tok::LParen,
                Tok::Ident("N".into()),
                Tok::RParen,
                Tok::Colon,
                Tok::Newline,
                Tok::Eof
            ]
        );
    }

    #[test]
    fn indent_and_comment() {
        let t = lex("a: f32[N] @ hbm\n  z[i] = x[i] # body\n").unwrap();
        assert!(t.contains(&Tok::Indent));
        assert!(!t.iter().any(|t| matches!(t, Tok::Ident(s) if s == "body")));
    }

    #[test]
    fn numbers() {
        let t = lex("6 0.125").unwrap();
        assert_eq!(t[0], Tok::Int(6));
        assert_eq!(t[1], Tok::Float(0.125));
    }

    #[test]
    fn rejects_garbage() {
        assert!(lex("a ~ b").is_err());
    }
}
