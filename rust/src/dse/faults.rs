//! Deterministic fault injection for the DSE supervision layer.
//!
//! A long-running `tvec dse --serve` daemon must survive panicking
//! tasklets, wedged simulations and failing cache writes — and the only
//! way to *prove* the supervision paths fire is to inject those faults
//! on demand, deterministically, so CI can grep for the classified
//! outcome. A [`FaultPlan`] is parsed from the `--inject-faults` spec
//! (grammar below) and attached to an [`crate::dse::Evaluator`]; each
//! armed fault names the exact evaluation ordinal (or cache
//! write-attempt index) it fires at, so the same spec against the same
//! sweep reproduces the same failure bit for bit.
//!
//! Spec grammar (DESIGN.md §14):
//!
//! ```text
//! spec      := injection ("," injection)*
//! injection := kind "@" index
//! kind      := "panic" | "wedge" | "slow" | "cachefail"
//! index     := decimal ≥ 0
//! ```
//!
//! * `panic@K` — the K-th *issued* evaluation (0-based; if that call
//!   is served from the memo cache the fault does not fire — a warm
//!   run never evaluates, so it is fault-free by construction) panics
//!   mid-candidate; supervision must classify it `FailKind::Panic` and
//!   keep the sweep alive.
//! * `wedge@K` — the K-th evaluation hangs; the wall-clock deadline
//!   (or a built-in fuse when none is armed) reaps it as
//!   `FailKind::Timeout`.
//! * `slow@K` — the K-th evaluation completes but only after sleeping
//!   past the armed wall deadline; the post-hoc budget check must still
//!   quarantine it as `FailKind::Timeout`.
//! * `cachefail@K` — the K-th physical cache write *attempt* fails;
//!   `cachefail@0` alone proves the bounded retry recovers, and
//!   consecutive indices covering every retry prove the degrade path.
//!
//! Evaluation ordinals are deterministic: the search issues candidates
//! from one thread in grid order, and batch evaluation reserves a
//! contiguous ordinal block up front, so worker interleaving cannot
//! reorder them.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// What an injected fault emulates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// A candidate evaluation panics (e.g. a buggy tasklet indexing out
    /// of bounds).
    Panic,
    /// A candidate evaluation hangs until reaped by the deadline.
    Wedge,
    /// A candidate evaluation completes, but past its wall budget.
    Slow,
    /// A physical cache write attempt fails (e.g. disk full).
    CacheFail,
}

impl FaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Panic => "panic",
            FaultKind::Wedge => "wedge",
            FaultKind::Slow => "slow",
            FaultKind::CacheFail => "cachefail",
        }
    }
}

/// How long a wedged evaluation is allowed to spin when no wall
/// deadline is armed before the built-in fuse reaps it anyway. The
/// fuse keeps `--inject-faults wedge@K` without `--deadline-ms` a
/// bounded experiment instead of a genuine hang.
pub const WEDGE_FUSE: Duration = Duration::from_secs(1);

/// How far past the armed wall deadline a `slow` injection sleeps:
/// enough margin that the post-hoc budget check fires deterministically
/// on any CI runner.
pub const SLOW_MARGIN: Duration = Duration::from_millis(50);

/// A parsed, seeded-by-construction fault schedule. All state is
/// atomic: the plan is shared behind the `Evaluator` across worker
/// threads.
#[derive(Debug, Default)]
pub struct FaultPlan {
    /// (evaluation ordinal, fault) — panic/wedge/slow injections.
    evals: Vec<(usize, FaultKind)>,
    /// Physical cache write-attempt indices that must fail.
    cache_fails: Vec<usize>,
    /// Write attempts observed so far (indexes into `cache_fails`).
    write_attempts: AtomicUsize,
    /// Injections that actually fired, by kind.
    fired_panic: AtomicUsize,
    fired_wedge: AtomicUsize,
    fired_slow: AtomicUsize,
    fired_cache: AtomicUsize,
}

impl FaultPlan {
    /// Parse an `--inject-faults` spec. See the module doc for the
    /// grammar.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (kind, index) = part.split_once('@').ok_or_else(|| {
                format!("bad fault '{part}': want <kind>@<index>, e.g. panic@2")
            })?;
            let index: usize = index
                .trim()
                .parse()
                .map_err(|_| format!("bad fault index in '{part}'"))?;
            match kind.trim() {
                "panic" => plan.evals.push((index, FaultKind::Panic)),
                "wedge" => plan.evals.push((index, FaultKind::Wedge)),
                "slow" => plan.evals.push((index, FaultKind::Slow)),
                "cachefail" => plan.cache_fails.push(index),
                other => {
                    return Err(format!(
                        "unknown fault kind '{other}' (want panic|wedge|slow|cachefail)"
                    ))
                }
            }
        }
        if plan.evals.is_empty() && plan.cache_fails.is_empty() {
            return Err("empty fault spec".into());
        }
        plan.evals.sort_by_key(|(i, _)| *i);
        plan.cache_fails.sort_unstable();
        Ok(plan)
    }

    /// The fault armed for evaluation ordinal `ordinal`, if any.
    pub fn at_eval(&self, ordinal: usize) -> Option<FaultKind> {
        self.evals
            .iter()
            .find(|(i, _)| *i == ordinal)
            .map(|(_, k)| *k)
    }

    /// Record that a fault fired (the supervisor calls this at the
    /// injection site so `summary()` reports armed-vs-fired honestly).
    pub fn note_fired(&self, kind: FaultKind) {
        let ctr = match kind {
            FaultKind::Panic => &self.fired_panic,
            FaultKind::Wedge => &self.fired_wedge,
            FaultKind::Slow => &self.fired_slow,
            FaultKind::CacheFail => &self.fired_cache,
        };
        ctr.fetch_add(1, Ordering::Relaxed);
    }

    /// Consume one physical cache write attempt; `true` means this
    /// attempt must fail. Attempt indices are global across the
    /// process, matching how a flaky disk doesn't care which flush is
    /// writing.
    pub fn cache_write_fails(&self) -> bool {
        let attempt = self.write_attempts.fetch_add(1, Ordering::Relaxed);
        let fails = self.cache_fails.binary_search(&attempt).is_ok();
        if fails {
            self.note_fired(FaultKind::CacheFail);
        }
        fails
    }

    /// Total injections armed by the spec.
    pub fn armed(&self) -> usize {
        self.evals.len() + self.cache_fails.len()
    }

    /// Total injections that fired so far.
    pub fn fired(&self) -> usize {
        self.fired_panic.load(Ordering::Relaxed)
            + self.fired_wedge.load(Ordering::Relaxed)
            + self.fired_slow.load(Ordering::Relaxed)
            + self.fired_cache.load(Ordering::Relaxed)
    }

    /// One line for the CLI report, e.g.
    /// `2 armed, 2 fired (panic 1, wedge 0, slow 1, cachefail 0)`.
    pub fn summary(&self) -> String {
        format!(
            "{} armed, {} fired (panic {}, wedge {}, slow {}, cachefail {})",
            self.armed(),
            self.fired(),
            self.fired_panic.load(Ordering::Relaxed),
            self.fired_wedge.load(Ordering::Relaxed),
            self.fired_slow.load(Ordering::Relaxed),
            self.fired_cache.load(Ordering::Relaxed),
        )
    }
}

/// Emulate a wedged evaluation: spin cooperatively (short sleeps, so
/// the thread stays reapable) until the armed wall deadline — or
/// [`WEDGE_FUSE`] when none is armed — has elapsed, then report how
/// long the wedge held the worker. The caller turns this into a
/// `FailKind::Timeout`.
pub fn wedge_spin(wall: Option<Duration>) -> Duration {
    let limit = wall.unwrap_or(WEDGE_FUSE) + SLOW_MARGIN;
    let start = Instant::now();
    while start.elapsed() < limit {
        std::thread::sleep(Duration::from_millis(5).min(limit));
    }
    start.elapsed()
}

/// Emulate a slow evaluation: sleep just past the armed wall deadline
/// (or [`SLOW_MARGIN`] alone when none is armed — benign, the candidate
/// then completes normally), then let the real evaluation proceed.
pub fn crawl(wall: Option<Duration>) {
    let nap = match wall {
        Some(w) => w + SLOW_MARGIN,
        None => SLOW_MARGIN,
    };
    std::thread::sleep(nap);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_ci_spec() {
        let plan = FaultPlan::parse("panic@2,slow@4").unwrap();
        assert_eq!(plan.armed(), 2);
        assert_eq!(plan.at_eval(2), Some(FaultKind::Panic));
        assert_eq!(plan.at_eval(4), Some(FaultKind::Slow));
        assert_eq!(plan.at_eval(0), None);
        assert_eq!(plan.fired(), 0);
    }

    #[test]
    fn parses_whitespace_and_all_kinds() {
        let plan = FaultPlan::parse(" wedge@1 , cachefail@0 , panic@9 ").unwrap();
        assert_eq!(plan.at_eval(1), Some(FaultKind::Wedge));
        assert_eq!(plan.at_eval(9), Some(FaultKind::Panic));
        assert_eq!(plan.armed(), 3);
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in ["", "panic", "panic@", "panic@x", "oops@1", "@3"] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn cache_write_attempts_fire_in_order() {
        let plan = FaultPlan::parse("cachefail@0,cachefail@2").unwrap();
        assert!(plan.cache_write_fails()); // attempt 0
        assert!(!plan.cache_write_fails()); // attempt 1
        assert!(plan.cache_write_fails()); // attempt 2
        assert!(!plan.cache_write_fails()); // attempt 3
        assert_eq!(plan.fired(), 2);
        assert!(plan.summary().contains("cachefail 2"), "{}", plan.summary());
    }

    #[test]
    fn fired_counters_track_notes() {
        let plan = FaultPlan::parse("panic@0,slow@1").unwrap();
        plan.note_fired(FaultKind::Panic);
        plan.note_fired(FaultKind::Slow);
        assert_eq!(plan.fired(), 2);
        assert_eq!(plan.summary(), "2 armed, 2 fired (panic 1, wedge 0, slow 1, cachefail 0)");
    }
}
