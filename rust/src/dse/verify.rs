//! Exact-simulator verification of search results (`tvec dse --verify`).
//!
//! The whole search ranks candidates by the **analytic rate model**
//! ([`crate::sim::rate_model`]) — O(#modules) per candidate, which is
//! what makes thousand-point sweeps affordable. The rate model is a
//! model, though, and a model that drifts would silently mis-rank the
//! frontier. This module re-runs frontier points through the **exact
//! cycle-stepped simulator** ([`crate::sim::run_exact`]) at *golden
//! scale* (the small problem sizes the AOT golden artifacts use, where
//! exact simulation is affordable) and fails loudly when the two
//! disagree beyond a tolerance.
//!
//! A point whose golden-scale rebuild is rejected by a legality check
//! (e.g. a vector width that divides the paper-scale extent but not
//! the golden one) is reported as *skipped* with the reason — visible,
//! never silent. A genuine compile error at golden scale is a failure:
//! the same configuration compiled at search scale, so lowering must
//! not break when only the bindings shrink.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::coordinator::pipeline::{compile_staged, BuildSpec, Stage};
use crate::sim::{is_timeout_error, rate_model, run_exact_deadline_in, Arena, Hbm};
use crate::telemetry::Recorder;
use crate::util::lock_unpoisoned;

use super::evaluate::{ArenaPool, Evaluation, Evaluator};

/// Accept rate-model vs exact-sim cycle ratios within ±40 % — the
/// envelope the simulator's own cross-validation tests use (vecadd
/// ±15 %, FW ±25 %, GEMM ±40 %).
pub const DEFAULT_TOLERANCE: f64 = 0.40;

/// Exact-sim cycle budget per verified point (slow cycles).
pub const MAX_VERIFY_CYCLES: u64 = 50_000_000;

/// Per-point budgets for supervised verification: a slow-cycle ceiling
/// and an optional wall-clock deadline. The default is the historical
/// behaviour — [`MAX_VERIFY_CYCLES`], no wall. A point that exhausts
/// either budget is reported as a *skip* with a `timed out:` reason
/// (visible, never silent, never fatal) — a deadline-bounded serving
/// daemon must degrade one verification, not abort the sweep.
#[derive(Clone, Copy, Debug)]
pub struct VerifyBudget {
    /// Exact-sim slow-cycle ceiling.
    pub max_cycles: u64,
    /// Wall-clock deadline for one point's exact simulation.
    pub wall: Option<Duration>,
}

impl Default for VerifyBudget {
    fn default() -> VerifyBudget {
        VerifyBudget { max_cycles: MAX_VERIFY_CYCLES, wall: None }
    }
}

impl VerifyBudget {
    /// The budgets the evaluator's armed limits imply (what
    /// `SearchConfig::with_limits` threaded through `run_search`).
    pub fn from_evaluator(evaluator: &Evaluator) -> VerifyBudget {
        VerifyBudget {
            max_cycles: evaluator.sim_cycle_budget(),
            wall: evaluator.wall_budget(),
        }
    }
}

/// One verified frontier point.
#[derive(Clone, Debug)]
pub struct VerifyReport {
    pub label: String,
    /// Analytic rate-model slow-cycle count at golden scale.
    pub rate_cycles: u64,
    /// Exact-simulator slow-cycle count at golden scale.
    pub exact_cycles: u64,
    /// `rate_cycles / exact_cycles` (1.0 = perfect agreement).
    pub ratio: f64,
    /// Within tolerance?
    pub within: bool,
    /// `Some(reason)` when the point could not be rebuilt at golden
    /// scale (legality at the smaller bindings) and was skipped.
    pub skipped: Option<String>,
}

/// Verify one evaluation's design point against a golden-scale base
/// spec. `inputs` are the HBM containers the exact run needs; the
/// exact simulation runs inside `arena`, so a caller verifying many
/// points on one arena (or through an [`ArenaPool`]) pays the slab
/// growth once and allocates nothing per transaction afterwards.
pub fn verify_point(
    golden_base: &BuildSpec,
    e: &Evaluation,
    inputs: &[(String, Vec<f32>)],
    tolerance: f64,
    arena: &mut Arena,
) -> Result<VerifyReport, String> {
    verify_point_observed(golden_base, e, inputs, tolerance, arena, None)
}

/// [`verify_point`] with an optional telemetry recorder: the point gets
/// a `dse.verify` span tagged with its label and outcome, and the exact
/// simulation inside runs observed (per-module busy/stall counters,
/// FIFO stall causes, per-domain utilization).
pub fn verify_point_observed(
    golden_base: &BuildSpec,
    e: &Evaluation,
    inputs: &[(String, Vec<f32>)],
    tolerance: f64,
    arena: &mut Arena,
    rec: Option<&Recorder>,
) -> Result<VerifyReport, String> {
    verify_point_budgeted(golden_base, e, inputs, tolerance, VerifyBudget::default(), arena, rec)
}

/// [`verify_point_observed`] under explicit per-point budgets. A point
/// that exhausts its slow-cycle ceiling or wall deadline comes back as
/// a skip (`timed out: …`) with a `timeout` span outcome.
#[allow(clippy::too_many_arguments)]
pub fn verify_point_budgeted(
    golden_base: &BuildSpec,
    e: &Evaluation,
    inputs: &[(String, Vec<f32>)],
    tolerance: f64,
    budget: VerifyBudget,
    arena: &mut Arena,
    rec: Option<&Recorder>,
) -> Result<VerifyReport, String> {
    let mut sp = rec.map(|r| r.span("dse.verify"));
    if let Some(s) = sp.as_mut() {
        s.note("label", &e.label);
    }
    let report = verify_point_inner(golden_base, e, inputs, tolerance, budget, arena, rec);
    if let Some(s) = sp.as_mut() {
        s.note(
            "outcome",
            match &report {
                Ok(r) if r.skipped.as_deref().is_some_and(|m| m.starts_with("timed out")) => {
                    "timeout"
                }
                Ok(r) if r.skipped.is_some() => "skipped",
                Ok(r) if r.within => "within",
                Ok(_) => "drift",
                Err(_) => "error",
            },
        );
    }
    report
}

fn verify_point_inner(
    golden_base: &BuildSpec,
    e: &Evaluation,
    inputs: &[(String, Vec<f32>)],
    tolerance: f64,
    budget: VerifyBudget,
    arena: &mut Arena,
    rec: Option<&Recorder>,
) -> Result<VerifyReport, String> {
    let spec = e.point.apply_to(golden_base);
    let c = match compile_staged(spec) {
        Ok(c) => c,
        Err(err) if matches!(err.stage, Stage::Transform | Stage::Bind) => {
            return Ok(VerifyReport {
                label: e.label.clone(),
                rate_cycles: 0,
                exact_cycles: 0,
                ratio: 0.0,
                within: false,
                skipped: Some(format!("not legal at golden scale: {}", err.message)),
            })
        }
        Err(err) => {
            return Err(format!(
                "{}: compile error at golden scale (compiled fine at search scale): {}",
                e.label, err.message
            ))
        }
    };
    let rate = rate_model(&c.design).slow_cycles;
    let mut hbm = Hbm::new();
    for (name, data) in inputs {
        hbm.load(name, data.clone());
    }
    let exact =
        match run_exact_deadline_in(&c.design, hbm, budget.max_cycles, budget.wall, arena, rec) {
            Ok(out) => out.stats.slow_cycles,
            // budget exhaustion (slow-cycle ceiling or wall deadline)
            // is a visible skip, not a fatal error: the candidate
            // already evaluated under the rate model, this re-check
            // simply could not afford to finish
            Err(err) if is_timeout_error(&err) => {
                if let Some(r) = rec {
                    r.add("dse.verify.timeouts", 1);
                }
                return Ok(VerifyReport {
                    label: e.label.clone(),
                    rate_cycles: rate,
                    exact_cycles: 0,
                    ratio: 0.0,
                    within: false,
                    skipped: Some(format!("timed out: {err}")),
                });
            }
            Err(err) => return Err(format!("{}: exact simulation failed: {err}", e.label)),
        };
    let ratio = rate as f64 / exact.max(1) as f64;
    Ok(VerifyReport {
        label: e.label.clone(),
        rate_cycles: rate,
        exact_cycles: exact,
        ratio,
        within: (ratio - 1.0).abs() <= tolerance,
        skipped: None,
    })
}

/// Verify every frontier point against its base's golden-scale spec
/// (`golden_bases[i]` corresponds to `SearchBase` index `i` of the
/// search that produced the frontier). Returns one report per point;
/// use [`failures`] to turn the reports into a hard pass/fail.
pub fn verify_frontier(
    frontier: &[Evaluation],
    golden_bases: &[BuildSpec],
    inputs: &[(String, Vec<f32>)],
    tolerance: f64,
) -> Result<Vec<VerifyReport>, String> {
    // a throwaway pool: sequential `run` calls reuse exactly one
    // arena, so the first simulation grows the slabs and the rest
    // recycle them — one loop definition shared with the pooled path
    verify_frontier_in(frontier, golden_bases, inputs, tolerance, &ArenaPool::default())
}

/// [`verify_frontier`] through a shared [`ArenaPool`] (the evaluator's
/// — `tvec dse --verify` reports the pool's counters afterwards).
pub fn verify_frontier_in(
    frontier: &[Evaluation],
    golden_bases: &[BuildSpec],
    inputs: &[(String, Vec<f32>)],
    tolerance: f64,
    pool: &ArenaPool,
) -> Result<Vec<VerifyReport>, String> {
    verify_frontier_observed(frontier, golden_bases, inputs, tolerance, pool, None)
}

/// [`verify_frontier_in`] with an optional telemetry recorder threaded
/// down to every point's span and exact simulation.
pub fn verify_frontier_observed(
    frontier: &[Evaluation],
    golden_bases: &[BuildSpec],
    inputs: &[(String, Vec<f32>)],
    tolerance: f64,
    pool: &ArenaPool,
    rec: Option<&Recorder>,
) -> Result<Vec<VerifyReport>, String> {
    verify_frontier_budgeted(
        frontier,
        golden_bases,
        inputs,
        tolerance,
        VerifyBudget::default(),
        pool,
        rec,
    )
}

/// [`verify_frontier_observed`] under explicit per-point budgets:
/// points that exhaust a budget come back as `timed out:` skips.
/// Sequential (one worker) — the parallel fan-out is
/// [`verify_frontier_pooled`].
#[allow(clippy::too_many_arguments)]
pub fn verify_frontier_budgeted(
    frontier: &[Evaluation],
    golden_bases: &[BuildSpec],
    inputs: &[(String, Vec<f32>)],
    tolerance: f64,
    budget: VerifyBudget,
    pool: &ArenaPool,
    rec: Option<&Recorder>,
) -> Result<Vec<VerifyReport>, String> {
    verify_frontier_pooled(frontier, golden_bases, inputs, tolerance, budget, pool, 1, rec)
}

/// [`verify_frontier_budgeted`] fanned across `threads` OS workers
/// (0 = available parallelism). Each worker checks out its own arena
/// from the shared pool, so concurrent points never contend on slabs
/// and a warm pool serves the whole batch allocation-free. Reports
/// come back in input order; when several points fail, the error of
/// the earliest point in input order is returned — same answer the
/// sequential loop gives, regardless of worker interleaving.
#[allow(clippy::too_many_arguments)]
pub fn verify_frontier_pooled(
    frontier: &[Evaluation],
    golden_bases: &[BuildSpec],
    inputs: &[(String, Vec<f32>)],
    tolerance: f64,
    budget: VerifyBudget,
    pool: &ArenaPool,
    threads: usize,
    rec: Option<&Recorder>,
) -> Result<Vec<VerifyReport>, String> {
    let n = frontier.len();
    let workers = crate::sim::resolve_threads(threads).min(n.max(1));
    if let Some(r) = rec {
        r.gauge("dse.verify.workers", workers as f64);
    }
    // resolve every point's golden base up front: a bad base index is
    // reported for the earliest offending point no matter which worker
    // would have reached it first
    let bases: Vec<Result<&BuildSpec, String>> =
        frontier.iter().map(|e| frontier_base(golden_bases, e)).collect();
    if workers <= 1 {
        let mut out = Vec::with_capacity(n);
        for (e, base) in frontier.iter().zip(&bases) {
            let base = base.as_ref().map_err(String::clone)?;
            out.push(pool.run(|arena| {
                verify_point_budgeted(base, e, inputs, tolerance, budget, arena, rec)
            })?);
        }
        return Ok(out);
    }
    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<Result<VerifyReport, String>>>> = Mutex::new(vec![None; n]);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = match &bases[i] {
                    Ok(base) => pool.run(|arena| {
                        verify_point_budgeted(
                            base,
                            &frontier[i],
                            inputs,
                            tolerance,
                            budget,
                            arena,
                            rec,
                        )
                    }),
                    Err(msg) => Err(msg.clone()),
                };
                lock_unpoisoned(&slots)[i] = Some(r);
            });
        }
    });
    let results = slots.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
    let mut out = Vec::with_capacity(n);
    for r in results {
        out.push(r.expect("every slot filled by a worker")?);
    }
    Ok(out)
}

/// [`verify_frontier_pooled`] reading its budgets, arena pool, and
/// worker count off the evaluator that ran the search — the supervised
/// serving path: whatever `--deadline-ms` / `--sim-cycle-budget` /
/// `--threads` armed for candidate evaluation also bounds the frontier
/// re-check.
pub fn verify_frontier_supervised(
    frontier: &[Evaluation],
    golden_bases: &[BuildSpec],
    inputs: &[(String, Vec<f32>)],
    tolerance: f64,
    evaluator: &Evaluator,
    rec: Option<&Recorder>,
) -> Result<Vec<VerifyReport>, String> {
    verify_frontier_pooled(
        frontier,
        golden_bases,
        inputs,
        tolerance,
        VerifyBudget::from_evaluator(evaluator),
        evaluator.arenas(),
        evaluator.threads(),
        rec,
    )
}

fn frontier_base<'a>(
    golden_bases: &'a [BuildSpec],
    e: &Evaluation,
) -> Result<&'a BuildSpec, String> {
    golden_bases.get(e.base).ok_or_else(|| {
        format!(
            "{}: no golden base for search base index {} ({} available)",
            e.label,
            e.base,
            golden_bases.len()
        )
    })
}

/// The labels of reports that ran and disagreed beyond tolerance.
pub fn failures(reports: &[VerifyReport]) -> Vec<&VerifyReport> {
    reports.iter().filter(|r| r.skipped.is_none() && !r.within).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::coordinator::BuildSpec;
    use crate::dse::evaluate::evaluate_point;
    use crate::dse::space::DesignPoint;
    use crate::ir::PumpMode;
    use crate::util::Rng;

    fn vecadd_golden() -> (BuildSpec, Vec<(String, Vec<f32>)>) {
        let n = apps::vecadd::GOLDEN_N;
        let spec = BuildSpec::new(apps::vecadd::build()).bind("N", n).seeded(9);
        let mut rng = Rng::new(2024);
        let inputs = vec![
            ("x".to_string(), rng.f32_vec(n as usize)),
            ("y".to_string(), rng.f32_vec(n as usize)),
        ];
        (spec, inputs)
    }

    fn eval_at_paper_scale(point: DesignPoint) -> Evaluation {
        let n = 1i64 << 20;
        let base = BuildSpec::new(apps::vecadd::build()).bind("N", n).seeded(9);
        evaluate_point(&base, &point, apps::vecadd::flops(n)).unwrap()
    }

    #[test]
    fn rate_model_agrees_with_exact_on_pumped_vecadd() {
        let (golden, inputs) = vecadd_golden();
        for pump in [None, Some((2, PumpMode::Resource))] {
            let e = eval_at_paper_scale(DesignPoint {
                vectorize: Some(("vadd".into(), 8)),
                pump,
                ..DesignPoint::original()
            });
            let r = verify_point(&golden, &e, &inputs, DEFAULT_TOLERANCE, &mut Arena::new())
                .unwrap();
            assert!(r.skipped.is_none());
            assert!(r.exact_cycles > 0 && r.rate_cycles > 0);
            assert!(
                r.within,
                "{}: rate {} vs exact {} (ratio {:.3})",
                r.label, r.rate_cycles, r.exact_cycles, r.ratio
            );
        }
    }

    #[test]
    fn pooled_verify_reuses_arena_slabs_across_points() {
        // two verifications of the same point through one pool: the
        // second must grow nothing (flat slots + flat high-water mark)
        let (golden, inputs) = vecadd_golden();
        let e = eval_at_paper_scale(DesignPoint {
            vectorize: Some(("vadd".into(), 8)),
            pump: Some((2, PumpMode::Resource)),
            ..DesignPoint::original()
        });
        let pool = ArenaPool::default();
        let points = vec![e.clone(), e];
        let reports =
            verify_frontier_in(&points, &[golden], &inputs, DEFAULT_TOLERANCE, &pool).unwrap();
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].exact_cycles, reports[1].exact_cycles);
        assert_eq!(pool.pooled(), 1, "sequential verify must reuse one arena");
        let s = pool.stats();
        assert!(s.slots > 0);
        assert!(s.recycle_hits > 0, "second verification must recycle the first's slots");
    }

    #[test]
    fn golden_scale_legality_rejection_is_a_visible_skip() {
        // width 8 is legal at N = 2^20 but not at a golden N of 100
        let spec = BuildSpec::new(apps::vecadd::build()).bind("N", 100).seeded(9);
        let e = eval_at_paper_scale(DesignPoint {
            vectorize: Some(("vadd".into(), 8)),
            ..DesignPoint::original()
        });
        let r = verify_point(&spec, &e, &[], DEFAULT_TOLERANCE, &mut Arena::new()).unwrap();
        let reason = r.skipped.expect("must be skipped, not failed");
        assert!(reason.contains("not legal at golden scale"), "{reason}");
    }

    #[test]
    fn exhausted_cycle_budget_is_a_visible_timeout_skip() {
        // a 1-slow-cycle ceiling cannot complete any real simulation
        let (golden, inputs) = vecadd_golden();
        let e = eval_at_paper_scale(DesignPoint {
            vectorize: Some(("vadd".into(), 8)),
            ..DesignPoint::original()
        });
        let budget = VerifyBudget { max_cycles: 1, wall: None };
        let r = verify_point_budgeted(
            &golden,
            &e,
            &inputs,
            DEFAULT_TOLERANCE,
            budget,
            &mut Arena::new(),
            None,
        )
        .unwrap();
        let reason = r.skipped.expect("must be skipped, not failed");
        assert!(reason.starts_with("timed out:"), "{reason}");
        assert!(r.rate_cycles > 0, "the rate model still priced the point");
    }

    #[test]
    fn exhausted_wall_deadline_is_a_visible_timeout_skip() {
        // a zero wall deadline reaps the simulation deterministically
        let (golden, inputs) = vecadd_golden();
        let e = eval_at_paper_scale(DesignPoint {
            vectorize: Some(("vadd".into(), 8)),
            ..DesignPoint::original()
        });
        let budget =
            VerifyBudget { max_cycles: MAX_VERIFY_CYCLES, wall: Some(Duration::ZERO) };
        let r = verify_point_budgeted(
            &golden,
            &e,
            &inputs,
            DEFAULT_TOLERANCE,
            budget,
            &mut Arena::new(),
            None,
        )
        .unwrap();
        let reason = r.skipped.expect("must be skipped, not failed");
        assert!(reason.starts_with("timed out:"), "{reason}");
        assert!(reason.contains("wall-clock deadline"), "{reason}");
    }

    #[test]
    fn supervised_budget_reads_the_evaluator_limits() {
        let ev = Evaluator::new();
        let b = VerifyBudget::from_evaluator(&ev);
        assert_eq!(b.max_cycles, MAX_VERIFY_CYCLES);
        assert!(b.wall.is_none());
        ev.set_limits(Some(250), Some(1_000));
        let armed = VerifyBudget::from_evaluator(&ev);
        assert_eq!(armed.max_cycles, 1_000);
        assert_eq!(armed.wall, Some(Duration::from_millis(250)));
    }

    #[test]
    fn verify_frontier_rejects_missing_base() {
        let (golden, inputs) = vecadd_golden();
        let mut e = eval_at_paper_scale(DesignPoint::original());
        e.base = 3; // no such base
        let err = verify_frontier(&[e], &[golden], &inputs, DEFAULT_TOLERANCE).unwrap_err();
        assert!(err.contains("no golden base"), "{err}");
    }

    #[test]
    fn parallel_verify_matches_sequential_and_records_workers() {
        let (golden, inputs) = vecadd_golden();
        let a = eval_at_paper_scale(DesignPoint {
            vectorize: Some(("vadd".into(), 8)),
            ..DesignPoint::original()
        });
        let b = eval_at_paper_scale(DesignPoint {
            vectorize: Some(("vadd".into(), 8)),
            pump: Some((2, PumpMode::Resource)),
            ..DesignPoint::original()
        });
        let points = vec![a, b];
        let serial =
            verify_frontier(&points, &[golden.clone()], &inputs, DEFAULT_TOLERANCE).unwrap();
        let rec = Recorder::new();
        let pool = ArenaPool::default();
        let parallel = verify_frontier_pooled(
            &points,
            &[golden],
            &inputs,
            DEFAULT_TOLERANCE,
            VerifyBudget::default(),
            &pool,
            2,
            Some(&rec),
        )
        .unwrap();
        assert_eq!(serial.len(), parallel.len());
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.label, p.label);
            assert_eq!(s.rate_cycles, p.rate_cycles);
            assert_eq!(s.exact_cycles, p.exact_cycles);
            assert_eq!(s.within, p.within);
        }
        assert_eq!(rec.gauges().get("dse.verify.workers"), Some(&2.0));
    }

    #[test]
    fn parallel_verify_reports_the_earliest_bad_base() {
        // the missing base sits at input index 0; whichever worker runs
        // point 1 first, the returned error must still be point 0's
        let (golden, inputs) = vecadd_golden();
        let mut bad = eval_at_paper_scale(DesignPoint::original());
        bad.base = 7;
        let good = eval_at_paper_scale(DesignPoint {
            vectorize: Some(("vadd".into(), 8)),
            ..DesignPoint::original()
        });
        let err = verify_frontier_pooled(
            &[bad, good],
            &[golden],
            &inputs,
            DEFAULT_TOLERANCE,
            VerifyBudget::default(),
            &ArenaPool::default(),
            2,
            None,
        )
        .unwrap_err();
        assert!(err.contains("no golden base for search base index 7"), "{err}");
    }

    #[test]
    fn failures_filter_excludes_skips() {
        let ok = VerifyReport {
            label: "ok".into(),
            rate_cycles: 100,
            exact_cycles: 100,
            ratio: 1.0,
            within: true,
            skipped: None,
        };
        let bad = VerifyReport { label: "bad".into(), ratio: 2.0, within: false, ..ok.clone() };
        let skip = VerifyReport {
            label: "skip".into(),
            within: false,
            skipped: Some("n/a".into()),
            ..ok.clone()
        };
        let reports = vec![ok, bad, skip];
        let f = failures(&reports);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].label, "bad");
    }
}
