//! Automatic design-space exploration and autotuning over the
//! multi-pumping pipeline.
//!
//! The paper frames multi-pumping as a superclass of vectorization and
//! hand-picks every design point — vector width, pump factor and mode,
//! SLR replica count — per application. This subsystem searches that
//! (spatial × temporal) space automatically:
//!
//! * [`space`] — candidate-grid generation driven by the legality
//!   analyses (vectorizability, temporal legality, stream-width
//!   divisibility) instead of brute force;
//! * [`evaluate`] — parallel candidate evaluation through the real
//!   compile pipeline, behind a content-hashed memoization cache so
//!   repeated sweeps are incremental;
//! * [`pareto`] — the resource-vs-throughput Pareto frontier and the
//!   two search objectives generalizing the paper's pumping modes
//!   (min-resource at iso-throughput / max-throughput at iso-resource);
//! * [`search`] — exhaustive and greedy (coordinate-descent) strategies
//!   with an early-cutoff evaluation budget.
//!
//! Entry points: `tvec dse --app <name>` on the CLI, the `dse`
//! experiment in [`crate::coordinator`], and `examples/autotune.rs`.

pub mod evaluate;
pub mod pareto;
pub mod search;
pub mod space;

pub use evaluate::{Evaluation, Evaluator};
pub use pareto::{dominates, frontier, resource_score, Objective};
pub use search::{run_search, SearchBase, SearchConfig, SearchOutcome, Strategy};
pub use space::{generate, DesignPoint, SpaceOptions};
