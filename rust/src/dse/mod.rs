//! Automatic design-space exploration and autotuning over the
//! multi-pumping pipeline.
//!
//! The paper frames multi-pumping as a superclass of vectorization and
//! hand-picks every design point — vector width, pump factor and mode,
//! SLR replica count — per application. This subsystem searches that
//! (spatial × temporal) space automatically:
//!
//! * [`space`] — candidate-grid generation driven by the legality
//!   analyses (vectorizability, temporal legality, stream-width
//!   divisibility) instead of brute force, including the *mixed
//!   per-region pump assignment* axis (`--mixed-factors`): one
//!   resource-mode factor per streamable region, legality pruned per
//!   region (DESIGN.md §7);
//! * [`evaluate`] — parallel candidate evaluation through the real
//!   compile pipeline, behind a content-hashed memoization cache so
//!   repeated sweeps are incremental;
//! * [`pareto`] — the resource-vs-throughput Pareto frontier and the
//!   two search objectives generalizing the paper's pumping modes
//!   (min-resource at iso-throughput / max-throughput at iso-resource);
//! * [`search`] — exhaustive, greedy (coordinate-descent), simulated
//!   annealing and successive-halving strategies with an early-cutoff
//!   evaluation budget;
//! * [`cache`] — the schema-versioned on-disk store behind
//!   `--cache-dir`: the memo cache persisted across processes, so
//!   repeated CLI invocations are incremental too;
//! * [`verify`] — exact-simulator spot checks of chosen frontier
//!   points at golden scale (`tvec dse --verify`), guarding the
//!   analytic rate model the whole search ranks on;
//! * [`faults`] — deterministic fault injection (`--inject-faults`):
//!   seeded candidate panics, wedges, slow evaluations and cache write
//!   failures, proving the supervision layer in [`evaluate`] classifies
//!   and quarantines every failure mode instead of dying (DESIGN.md
//!   §14).
//!
//! Entry points: `tvec dse --app <name>` on the CLI, `tvec dse --serve`
//! for the long-running daemon, the `dse` experiment in
//! [`crate::coordinator`], and `examples/autotune.rs`.

pub mod cache;
pub mod evaluate;
pub mod faults;
pub mod pareto;
pub mod search;
pub mod space;
pub mod verify;

pub use evaluate::{ArenaPool, EvalError, Evaluation, Evaluator, FailKind};
pub use faults::{FaultKind, FaultPlan};
pub use pareto::{dominates, frontier, resource_score, Objective};
pub use search::{run_search, SearchBase, SearchConfig, SearchOutcome, Strategy};
pub use space::{generate, DesignPoint, SpaceOptions};
pub use verify::{
    verify_frontier, verify_frontier_budgeted, verify_frontier_in, verify_frontier_observed,
    verify_frontier_pooled, verify_frontier_supervised, VerifyBudget, VerifyReport,
    DEFAULT_TOLERANCE,
};
