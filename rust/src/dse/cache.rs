//! Persistent on-disk store for the evaluation memo cache.
//!
//! Layout: one text file, one record per line ("JSON-lines" style, but
//! tab-separated `key=value` tokens so it parses with zero dependencies
//! — DESIGN.md §6). The first line is a schema-versioned header:
//!
//! ```text
//! #tvec-dse-cache v6
//! k=00ab…	st=ok	label=vecadd V8 R2	pr=-	…
//! k=11cd…	st=ok	label=jacobi Mx[t2x1+2x3]	pr=m:2t,2r,2r,2r	…
//! k=17ff…	st=err	kind=legality	msg=trip count 100 …
//! ```
//!
//! Records are *tagged* `key=value` fields, so the layout is
//! forward-compatible: a reader ignores fields it does not know,
//! meaning a later schema can add fields without breaking this
//! version's parser — only a field *removal*, a value re-encoding or a
//! fingerprint re-derivation forces the version bump / cold start.
//!
//! Floats are stored as their IEEE-754 bit patterns (16 hex digits) so
//! a round trip is *bit exact* — the cache-hit determinism guarantees
//! of the in-memory cache carry over to the disk tier. Values are
//! percent-escaped (`%`, tab, CR, LF), so labels and error messages
//! survive verbatim.
//!
//! Failure policy: a missing file is a silent cold start; an
//! unreadable, version-mismatched, truncated or otherwise corrupt file
//! is a cold start *with a reason* — never a crash and never a
//! half-loaded store (a file that fails to parse anywhere is dropped
//! whole, because a partially applied store could mask real entries on
//! the next merge). Writes go to a temp file and are renamed into
//! place, so a crashed writer leaves the previous store intact.
//! Flushes merge with a fresh re-read of the file under the advisory
//! [`FlushLock`] (`<store>.lock`, best-effort `create_new` with
//! bounded retry), so the serve daemon and a concurrent CLI run cannot
//! drop each other's entries; a flusher that cannot take the lock
//! *skips* its flush with a warning rather than blocking or racing —
//! entries stay in memory for the next flush. Transient write failures
//! retry with bounded backoff ([`save_retry`]); the evaluator degrades
//! to in-memory-only when retries are exhausted.

use std::collections::HashMap;
use std::path::Path;

use crate::hw::{ClockReport, ResourceVec, Utilization};
use crate::ir::{PumpMode, RegionPump};

use super::evaluate::{EvalError, Evaluation, FailKind};
use super::space::DesignPoint;
use crate::codegen::DesignReport;

/// Bump on any change to the record layout *or* the fingerprint key
/// derivation: old stores then load cold instead of misparsing (or
/// silently never hitting). v2 added the mixed per-region pump
/// assignment (`pr=`) to ok-records; v3 re-derived fingerprints from
/// the cached base-graph hash; v4 made pump assignments mode-carrying
/// (`pp=` gained bare-fast `b`, `pr=` entries became `<factor><mode>`
/// like `2t`), which changed both the `pr=` value encoding and the
/// fingerprint tags, so v3 records could never hit again; v5 added the
/// design-rule checker gate, whose `check`-kind failures old readers
/// would reject as a bad failure kind; v6 added the supervision
/// failure kinds `panic`/`timeout` to the record grammar (the
/// evaluator quarantines them and never *flushes* them, but the codec
/// must round-trip them, and a v5 reader would reject such a record as
/// a bad failure kind). Older files cold-start with the
/// schema-mismatch reason.
pub const SCHEMA_VERSION: u32 = 6;

/// File name inside a `--cache-dir`.
pub const FILE_NAME: &str = "dse_cache.tsv";

fn header() -> String {
    format!("#tvec-dse-cache v{SCHEMA_VERSION}")
}

/// The result of loading a store.
pub struct Loaded {
    pub entries: HashMap<u64, Result<Evaluation, EvalError>>,
    /// `Some(reason)` when a present store was discarded.
    pub cold_reason: Option<String>,
}

// ---- escaping -------------------------------------------------------

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '%' => out.push_str("%25"),
            '\t' => out.push_str("%09"),
            '\n' => out.push_str("%0A"),
            '\r' => out.push_str("%0D"),
            _ => out.push(c),
        }
    }
    out
}

fn unescape(s: &str) -> Result<String, String> {
    let mut out = String::with_capacity(s.len());
    let bytes = s.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'%' {
            let hex = s.get(i + 1..i + 3).ok_or("truncated escape")?;
            let v = u8::from_str_radix(hex, 16).map_err(|_| "bad escape")?;
            out.push(v as char);
            i += 3;
        } else {
            // char boundaries: push the full char
            let c = s[i..].chars().next().unwrap();
            out.push(c);
            i += c.len_utf8();
        }
    }
    Ok(out)
}

// ---- primitive field codecs ----------------------------------------

fn f64_enc(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

fn f64_dec(s: &str) -> Result<f64, String> {
    u64::from_str_radix(s, 16)
        .map(f64::from_bits)
        .map_err(|_| format!("bad f64 bits '{s}'"))
}

fn fvec_enc(vs: &[f64]) -> String {
    vs.iter().map(|v| f64_enc(*v)).collect::<Vec<_>>().join(",")
}

fn fvec_dec(s: &str, n: usize) -> Result<Vec<f64>, String> {
    let parts: Vec<&str> = s.split(',').collect();
    if parts.len() != n {
        return Err(format!("expected {n} floats, got {}", parts.len()));
    }
    parts.iter().map(|p| f64_dec(p)).collect()
}

fn clock_enc(c: &ClockReport) -> String {
    fvec_enc(&[c.achieved_mhz, c.requested_mhz, c.congestion])
}

fn clock_dec(s: &str) -> Result<ClockReport, String> {
    let v = fvec_dec(s, 3)?;
    Ok(ClockReport { achieved_mhz: v[0], requested_mhz: v[1], congestion: v[2] })
}

fn res_enc(r: &ResourceVec) -> String {
    fvec_enc(&[r.lut_logic, r.lut_memory, r.registers, r.bram, r.dsp])
}

fn res_dec(s: &str) -> Result<ResourceVec, String> {
    let v = fvec_dec(s, 5)?;
    Ok(ResourceVec::new(v[0], v[1], v[2], v[3], v[4]))
}

fn util_dec(s: &str) -> Result<Utilization, String> {
    let v = fvec_dec(s, 5)?;
    Ok(Utilization {
        lut_logic: v[0],
        lut_memory: v[1],
        registers: v[2],
        bram: v[3],
        dsp: v[4],
    })
}

fn pump_enc(p: &Option<(usize, PumpMode)>) -> String {
    match p {
        None => "-".into(),
        Some((f, m)) => format!("{}{f}", m.letter()),
    }
}

fn mode_of_letter(s: &str) -> Option<PumpMode> {
    match s {
        "r" => Some(PumpMode::Resource),
        "t" => Some(PumpMode::Throughput),
        "b" => Some(PumpMode::BareFast),
        _ => None,
    }
}

fn pump_dec(s: &str) -> Result<Option<(usize, PumpMode)>, String> {
    if s == "-" {
        return Ok(None);
    }
    let (mode, digits) = s.split_at(1);
    let f: usize = digits.parse().map_err(|_| format!("bad pump '{s}'"))?;
    match mode_of_letter(mode) {
        Some(m) => Ok(Some((f, m))),
        None => Err(format!("bad pump mode '{s}'")),
    }
}

fn vec_opt_enc(v: &Option<(String, usize)>) -> String {
    match v {
        None => "-".into(),
        Some((map, w)) => format!("{w}:{}", escape(map)),
    }
}

fn vec_opt_dec(s: &str) -> Result<Option<(String, usize)>, String> {
    if s == "-" {
        return Ok(None);
    }
    let (w, map) = s.split_once(':').ok_or_else(|| format!("bad vectorize '{s}'"))?;
    let w: usize = w.parse().map_err(|_| format!("bad width '{s}'"))?;
    Ok(Some((unescape(map)?, w)))
}

// encoding shared with the fingerprint tag: `super::evaluate::regions_tag`
// (each entry `<factor><mode letter>`, e.g. `2r`, `4t`, `2b`, or `-`)

fn regions_dec(s: &str) -> Result<Option<Vec<Option<RegionPump>>>, String> {
    if s == "-" {
        return Ok(None);
    }
    let body = s.strip_prefix("m:").ok_or_else(|| format!("bad regions '{s}'"))?;
    body.split(',')
        .map(|t| {
            if t == "-" {
                return Ok(None);
            }
            let mode = t
                .chars()
                .last()
                .and_then(|c| mode_of_letter(&c.to_string()))
                .ok_or_else(|| format!("bad region mode '{t}'"))?;
            // the matched letter is one ASCII byte, so this split is safe
            let factor: usize = t[..t.len() - 1]
                .parse()
                .map_err(|_| format!("bad region factor '{t}'"))?;
            Ok(Some(RegionPump::new(factor, mode)))
        })
        .collect::<Result<Vec<_>, _>>()
        .map(Some)
}

fn opt_f64_enc(v: &Option<f64>) -> String {
    match v {
        None => "-".into(),
        Some(x) => f64_enc(*x),
    }
}

fn opt_f64_dec(s: &str) -> Result<Option<f64>, String> {
    if s == "-" {
        return Ok(None);
    }
    Ok(Some(f64_dec(s)?))
}

// ---- record codec ---------------------------------------------------

fn encode_record(key: u64, entry: &Result<Evaluation, EvalError>) -> String {
    match entry {
        Err(e) => format!(
            "k={key:016x}\tst=err\tkind={}\tmsg={}",
            e.kind.name(),
            escape(&e.message)
        ),
        Ok(ev) => {
            let r = &ev.report;
            let cl1 = r.cl1.as_ref().map(clock_enc).unwrap_or_else(|| "-".into());
            let u = [
                r.util.lut_logic,
                r.util.lut_memory,
                r.util.registers,
                r.util.bram,
                r.util.dsp,
            ];
            format!(
                "k={key:016x}\tst=ok\tlabel={}\tpv={}\tpp={}\tpr={}\trep={}\tpclk={}\t\
                 name={}\tres={}\tutil={}\tcl0={}\tcl1={}\teff={}\tpf={}\t\
                 cyc={}\ttime={}\tgops={}\ttot={}\tscore={}\tfits={}",
                escape(&ev.label),
                vec_opt_enc(&ev.point.vectorize),
                pump_enc(&ev.point.pump),
                super::evaluate::regions_tag(&ev.point.regions),
                ev.point.replicas,
                opt_f64_enc(&ev.point.cl0_request_mhz),
                escape(&r.name),
                res_enc(&r.resources),
                fvec_enc(&u),
                clock_enc(&r.cl0),
                cl1,
                f64_enc(r.effective_mhz),
                r.pump_factor,
                ev.slow_cycles,
                f64_enc(ev.time_s),
                f64_enc(ev.gops),
                res_enc(&ev.total_resources),
                f64_enc(ev.resource_score),
                ev.fits as u8,
            )
        }
    }
}

fn decode_record(line: &str) -> Result<(u64, Result<Evaluation, EvalError>), String> {
    let mut fields: HashMap<&str, &str> = HashMap::new();
    for tok in line.split('\t') {
        let (k, v) = tok.split_once('=').ok_or_else(|| format!("bad token '{tok}'"))?;
        fields.insert(k, v);
    }
    let get = |name: &str| -> Result<&str, String> {
        fields.get(name).copied().ok_or_else(|| format!("missing field '{name}'"))
    };
    let key = u64::from_str_radix(get("k")?, 16).map_err(|_| "bad key".to_string())?;
    match get("st")? {
        "err" => {
            let kind = match get("kind")? {
                "legality" => FailKind::Legality,
                "compile" => FailKind::Compile,
                "check" => FailKind::Check,
                "panic" => FailKind::Panic,
                "timeout" => FailKind::Timeout,
                other => return Err(format!("bad failure kind '{other}'")),
            };
            let message = unescape(get("msg")?)?;
            Ok((key, Err(EvalError { kind, message })))
        }
        "ok" => {
            let cl1 = match get("cl1")? {
                "-" => None,
                s => Some(clock_dec(s)?),
            };
            let report = DesignReport {
                name: unescape(get("name")?)?,
                resources: res_dec(get("res")?)?,
                util: util_dec(get("util")?)?,
                cl0: clock_dec(get("cl0")?)?,
                cl1,
                effective_mhz: f64_dec(get("eff")?)?,
                pump_factor: get("pf")?.parse().map_err(|_| "bad pf".to_string())?,
            };
            let point = DesignPoint {
                vectorize: vec_opt_dec(get("pv")?)?,
                pump: pump_dec(get("pp")?)?,
                regions: regions_dec(get("pr")?)?,
                replicas: get("rep")?.parse().map_err(|_| "bad rep".to_string())?,
                cl0_request_mhz: opt_f64_dec(get("pclk")?)?,
            };
            let ev = Evaluation {
                label: unescape(get("label")?)?,
                point,
                base: 0,
                report,
                slow_cycles: get("cyc")?.parse().map_err(|_| "bad cyc".to_string())?,
                time_s: f64_dec(get("time")?)?,
                gops: f64_dec(get("gops")?)?,
                total_resources: res_dec(get("tot")?)?,
                resource_score: f64_dec(get("score")?)?,
                fits: get("fits")? == "1",
            };
            Ok((key, Ok(ev)))
        }
        other => Err(format!("bad status '{other}'")),
    }
}

// ---- store API ------------------------------------------------------

/// Load a store. Missing file → empty, no reason. Present but
/// unreadable / wrong version / corrupt anywhere → empty, with the
/// reason recorded. Never an error.
pub fn load(path: &Path) -> Loaded {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Loaded { entries: HashMap::new(), cold_reason: None }
        }
        Err(e) => {
            return Loaded {
                entries: HashMap::new(),
                cold_reason: Some(format!("unreadable cache ({e}); cold start")),
            }
        }
    };
    let cold = |reason: String| Loaded {
        entries: HashMap::new(),
        cold_reason: Some(format!("{reason}; cold start")),
    };
    let mut lines = text.lines();
    match lines.next() {
        Some(h) if h == header() => {}
        Some(h) if h.starts_with("#tvec-dse-cache") => {
            return cold(format!("schema mismatch (file '{h}', want '{}')", header()))
        }
        _ => return cold("unrecognized cache header".into()),
    }
    let mut entries = HashMap::new();
    for (i, line) in lines.enumerate() {
        if line.is_empty() {
            continue;
        }
        match decode_record(line) {
            Ok((k, v)) => {
                entries.insert(k, v);
            }
            Err(e) => return cold(format!("corrupt record at line {} ({e})", i + 2)),
        }
    }
    Loaded { entries, cold_reason: None }
}

/// Merge `from` into `into`. Existing entries win (keys are content
/// hashes, so colliding entries should be identical anyway).
pub fn merge(
    into: &mut HashMap<u64, Result<Evaluation, EvalError>>,
    from: HashMap<u64, Result<Evaluation, EvalError>>,
) {
    for (k, v) in from {
        into.entry(k).or_insert(v);
    }
}

/// Write a store atomically (temp file + rename). Records are sorted
/// by key so identical caches serialize identically.
pub fn save(
    path: &Path,
    entries: &HashMap<u64, Result<Evaluation, EvalError>>,
) -> Result<(), String> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).map_err(|e| format!("create {}: {e}", dir.display()))?;
    }
    let mut keys: Vec<&u64> = entries.keys().collect();
    keys.sort();
    let mut text = header();
    text.push('\n');
    for k in keys {
        text.push_str(&encode_record(*k, &entries[k]));
        text.push('\n');
    }
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    std::fs::write(&tmp, text).map_err(|e| format!("write {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path).map_err(|e| format!("rename {}: {e}", path.display()))
}

/// Physical write attempts per [`save_retry`] call: the first try plus
/// [`IO_RETRIES`] retries.
pub const IO_RETRIES: usize = 3;

/// Base delay before the first retry; doubles per retry (10/20/40 ms —
/// transient-blip scale, not outage scale: a flush that cannot land in
/// ~70 ms degrades instead of stalling the sweep).
pub const IO_RETRY_DELAY: std::time::Duration = std::time::Duration::from_millis(10);

/// [`save`] with bounded-backoff retry on transient IO failure (write
/// or rename errors — disk full, racing cleanup). When a fault plan is
/// attached, injected `cachefail@K` faults consume write-attempt
/// indices here, so `cachefail@0` alone proves recovery on retry and a
/// run of consecutive indices proves the degrade path. Returns the
/// last error once all attempts are spent.
pub fn save_retry(
    path: &Path,
    entries: &HashMap<u64, Result<Evaluation, EvalError>>,
    faults: Option<&super::faults::FaultPlan>,
) -> Result<(), String> {
    let mut last = String::new();
    for attempt in 0..=IO_RETRIES {
        if attempt > 0 {
            std::thread::sleep(IO_RETRY_DELAY * (1u32 << (attempt - 1)));
        }
        if let Some(plan) = faults {
            if plan.cache_write_fails() {
                last = format!("injected cache write failure (attempt {attempt})");
                continue;
            }
        }
        match save(path, entries) {
            Ok(()) => return Ok(()),
            Err(e) => last = e,
        }
    }
    Err(format!("{last} (after {} attempts)", IO_RETRIES + 1))
}

/// Attempts to take the advisory flush lock before giving up.
pub const LOCK_RETRIES: usize = 5;

/// Delay between flush-lock attempts. A merging flush holds the lock
/// for one read + one write — milliseconds — so a handful of 20 ms
/// retries rides out any live contender; anything longer is either a
/// wedged flusher (stale detection takes over) or genuinely sustained
/// contention (skip-and-warn takes over).
pub const LOCK_RETRY_DELAY: std::time::Duration = std::time::Duration::from_millis(20);

/// A lock file older than this is presumed leaked by a crashed flusher
/// (the drop guard normally removes it) and is broken.
pub const LOCK_STALE_AFTER: std::time::Duration = std::time::Duration::from_secs(10);

/// Advisory cross-process flush lock: `<store>.lock` created with
/// `create_new` (atomic on every platform the store targets), removed
/// on drop. Best-effort by design — callers that fail to acquire skip
/// their flush and warn rather than block, and a stale lock (older
/// than [`LOCK_STALE_AFTER`]) is broken so one crashed flusher cannot
/// wedge every future flush.
pub struct FlushLock {
    path: std::path::PathBuf,
}

impl FlushLock {
    /// Try to take the flush lock for `store`, with bounded retry.
    /// `None` means a live contender held it the whole time (or the
    /// directory is unwritable) — skip the flush.
    pub fn acquire(store: &Path) -> Option<FlushLock> {
        let path = store.with_extension("lock");
        if let Some(dir) = path.parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        for attempt in 0..=LOCK_RETRIES {
            match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(_) => return Some(FlushLock { path }),
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let stale = std::fs::metadata(&path)
                        .and_then(|md| md.modified())
                        .ok()
                        .and_then(|m| m.elapsed().ok())
                        .is_some_and(|age| age > LOCK_STALE_AFTER);
                    if stale {
                        // break it and retry immediately: the remove
                        // may race another breaker, but the next
                        // create_new arbitrates
                        let _ = std::fs::remove_file(&path);
                    } else if attempt < LOCK_RETRIES {
                        std::thread::sleep(LOCK_RETRY_DELAY);
                    }
                }
                // unwritable directory etc.: same answer as contention
                Err(_) => return None,
            }
        }
        None
    }
}

impl Drop for FlushLock {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Raw record count of a store file (non-empty lines minus the
/// header), independent of whether the records parse — a stale-schema
/// file still reports its size, which is exactly what compaction is
/// about to reclaim. 0 for a missing/unreadable file.
pub fn count_records(path: &Path) -> usize {
    match std::fs::read_to_string(path) {
        Ok(text) => text
            .lines()
            .filter(|l| !l.is_empty() && !l.starts_with('#'))
            .count(),
        Err(_) => 0,
    }
}

/// Compacting rewrite: replace the store with exactly `entries`,
/// dropping whatever else the file held — superseded records, and
/// records whose schema (header *or* fingerprint derivation) no longer
/// matches and therefore could never hit again. The inverse of the
/// merging [`save`]-after-[`load`] flush, used by `--cache-compact` so
/// month-scale stores stop growing append-only. Returns
/// `(records on disk before, records written)`.
pub fn compact(
    path: &Path,
    entries: &HashMap<u64, Result<Evaluation, EvalError>>,
) -> Result<(usize, usize), String> {
    let before = count_records(path);
    save(path, entries)?;
    Ok((before, entries.len()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::coordinator::BuildSpec;
    use crate::dse::evaluate::{evaluate_point, fingerprint};

    fn tmp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!(
            "tvec-cache-test-{}-{tag}.tsv",
            std::process::id()
        ))
    }

    fn sample_entries() -> HashMap<u64, Result<Evaluation, EvalError>> {
        let base = BuildSpec::new(apps::vecadd::build()).bind("N", 1 << 12).seeded(3);
        let flops = apps::vecadd::flops(1 << 12);
        let mut m = HashMap::new();
        for (w, pump) in [
            (4usize, None),
            (8, Some((2, PumpMode::Resource))),
            (8, Some((2, PumpMode::Throughput))),
        ] {
            let p = DesignPoint {
                vectorize: Some(("vadd".into(), w)),
                pump,
                ..DesignPoint::original()
            };
            let key = fingerprint(&base, &p, flops);
            m.insert(key, evaluate_point(&base, &p, flops));
        }
        // a mixed per-region evaluation (the single-region assignment
        // delegates to the uniform transform, so it compiles)
        let mixed = DesignPoint {
            vectorize: Some(("vadd".into(), 8)),
            regions: Some(vec![Some(RegionPump::resource(2))]),
            ..DesignPoint::original()
        };
        m.insert(
            fingerprint(&base, &mixed, flops),
            evaluate_point(&base, &mixed, flops),
        );
        m.insert(
            0xdead,
            Err(EvalError::legality("N = 100 does not divide by 8")),
        );
        m.insert(0xbeef, Err(EvalError::compile("lowering exploded %\t weirdly")));
        m.insert(
            0xfeed,
            Err(EvalError::check("TV011 error `s_fast`: capacity 1 below minimum safe depth 4")),
        );
        m
    }

    #[test]
    fn round_trip_is_bit_exact() {
        let path = tmp_path("roundtrip");
        let entries = sample_entries();
        save(&path, &entries).unwrap();
        let loaded = load(&path);
        assert!(loaded.cold_reason.is_none());
        assert_eq!(loaded.entries.len(), entries.len());
        for (k, v) in &entries {
            let got = loaded.entries.get(k).expect("key survived");
            match (v, got) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(a.label, b.label);
                    assert_eq!(a.point, b.point);
                    assert_eq!(a.slow_cycles, b.slow_cycles);
                    // bit-exact floats
                    assert_eq!(a.gops.to_bits(), b.gops.to_bits());
                    assert_eq!(a.time_s.to_bits(), b.time_s.to_bits());
                    assert_eq!(a.resource_score.to_bits(), b.resource_score.to_bits());
                    assert_eq!(a.report.effective_mhz.to_bits(), b.report.effective_mhz.to_bits());
                    assert_eq!(a.report.resources, b.report.resources);
                    assert_eq!(a.report.util, b.report.util);
                    assert_eq!(
                        a.report.cl0.achieved_mhz.to_bits(),
                        b.report.cl0.achieved_mhz.to_bits()
                    );
                    assert_eq!(
                        a.report.cl1.map(|c| c.achieved_mhz.to_bits()),
                        b.report.cl1.map(|c| c.achieved_mhz.to_bits())
                    );
                    assert_eq!(a.fits, b.fits);
                }
                (Err(a), Err(b)) => assert_eq!(a, b),
                _ => panic!("ok/err mismatch for key {k:x}"),
            }
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn merge_unions_two_stores() {
        let (pa, pb) = (tmp_path("merge-a"), tmp_path("merge-b"));
        let all = sample_entries();
        let mut a = HashMap::new();
        let mut b = HashMap::new();
        for (i, (k, v)) in all.iter().enumerate() {
            if i % 2 == 0 {
                a.insert(*k, v.clone());
            } else {
                b.insert(*k, v.clone());
            }
        }
        save(&pa, &a).unwrap();
        save(&pb, &b).unwrap();
        let mut merged = load(&pa).entries;
        merge(&mut merged, load(&pb).entries);
        assert_eq!(merged.len(), all.len());
        for k in all.keys() {
            assert!(merged.contains_key(k));
        }
        let _ = std::fs::remove_file(&pa);
        let _ = std::fs::remove_file(&pb);
    }

    #[test]
    fn schema_version_mismatch_is_cold_start() {
        let path = tmp_path("version");
        std::fs::write(&path, "#tvec-dse-cache v999\nk=0\tst=err\tkind=legality\tmsg=x\n")
            .unwrap();
        let loaded = load(&path);
        assert!(loaded.entries.is_empty());
        let reason = loaded.cold_reason.expect("has a reason");
        assert!(reason.contains("schema mismatch"), "{reason}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn old_version_stores_cold_start_with_printed_reason() {
        // v1 (pre-mixed-factors), v2 (pre-rekeyed-fingerprint), v3
        // (pre-mode-carrying-pumps), v4 (pre-checker-gate) and v5
        // (pre-supervision-kinds) stores must load cold with the
        // schema-mismatch reason, never misparse or silently never-hit
        for old in ["v1", "v2", "v3", "v4", "v5"] {
            let path = tmp_path(&format!("{old}-upgrade"));
            std::fs::write(
                &path,
                format!(
                    "#tvec-dse-cache {old}\nk=00000000000000ab\tst=err\tkind=legality\tmsg=old\n"
                ),
            )
            .unwrap();
            let loaded = load(&path);
            assert!(loaded.entries.is_empty(), "{old} entries must not half-load into v6");
            let reason = loaded.cold_reason.expect("cold start has a reason");
            assert!(reason.contains("schema mismatch") && reason.contains(old), "{reason}");
            assert!(reason.contains("v6"), "{reason}");
            let _ = std::fs::remove_file(&path);
        }
    }

    #[test]
    fn supervision_failure_kinds_round_trip_through_the_codec() {
        // the evaluator never *flushes* quarantined entries, but the
        // v6 record grammar must round-trip them (codec symmetry — and
        // a belt-and-braces path if a future policy persists them)
        let path = tmp_path("supervision-kinds");
        let mut m: HashMap<u64, Result<Evaluation, EvalError>> = HashMap::new();
        m.insert(0x1, Err(EvalError::panicked("evaluation #2 panicked: boom")));
        m.insert(0x2, Err(EvalError::timeout("evaluation #4 exceeded its 50ms wall budget")));
        save(&path, &m).unwrap();
        let loaded = load(&path);
        assert!(loaded.cold_reason.is_none(), "{:?}", loaded.cold_reason);
        assert_eq!(loaded.entries.len(), 2);
        assert_eq!(
            loaded.entries[&0x1].as_ref().unwrap_err().kind,
            FailKind::Panic
        );
        assert_eq!(
            loaded.entries[&0x2].as_ref().unwrap_err().kind,
            FailKind::Timeout
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn save_retry_recovers_from_one_injected_write_failure() {
        use crate::dse::faults::FaultPlan;
        let path = tmp_path("retry-recovers");
        let plan = FaultPlan::parse("cachefail@0").unwrap();
        let entries = sample_entries();
        save_retry(&path, &entries, Some(&plan)).unwrap();
        assert_eq!(load(&path).entries.len(), entries.len());
        assert_eq!(plan.fired(), 1, "the injected failure must have consumed attempt 0");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn save_retry_exhausts_when_every_attempt_fails() {
        use crate::dse::faults::FaultPlan;
        let path = tmp_path("retry-exhausts");
        // one injected failure per physical attempt (first + IO_RETRIES)
        let spec = (0..=IO_RETRIES)
            .map(|i| format!("cachefail@{i}"))
            .collect::<Vec<_>>()
            .join(",");
        let plan = FaultPlan::parse(&spec).unwrap();
        let err = save_retry(&path, &sample_entries(), Some(&plan)).unwrap_err();
        assert!(err.contains("after 4 attempts"), "{err}");
        assert!(!path.exists(), "no write may have landed");
        // the *next* flush (fresh attempt indices past the plan) succeeds
        save_retry(&path, &sample_entries(), Some(&plan)).unwrap();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn flush_lock_excludes_and_releases() {
        let store = tmp_path("lock-basic");
        let first = FlushLock::acquire(&store).expect("uncontended acquire");
        // a contender spins its bounded retries, then gives up
        assert!(
            FlushLock::acquire(&store).is_none(),
            "second acquire must fail while the first is held"
        );
        drop(first);
        // drop released the file: acquire works again
        let again = FlushLock::acquire(&store).expect("acquire after release");
        drop(again);
        assert!(!store.with_extension("lock").exists());
    }

    #[test]
    fn flush_lock_breaks_stale_locks() {
        let store = tmp_path("lock-stale");
        let lock_path = store.with_extension("lock");
        std::fs::write(&lock_path, "").unwrap();
        // age the lock file past the stale horizon
        let old = std::time::SystemTime::now() - (LOCK_STALE_AFTER + LOCK_STALE_AFTER);
        let f = std::fs::OpenOptions::new().write(true).open(&lock_path).unwrap();
        f.set_modified(old).unwrap();
        drop(f);
        let lock = FlushLock::acquire(&store);
        assert!(lock.is_some(), "a stale lock must be broken, not honored");
        drop(lock);
        assert!(!lock_path.exists());
    }

    #[test]
    fn unknown_fields_are_tolerated() {
        // forward compatibility within a schema version: a record that
        // carries fields this reader does not know (e.g. written by a
        // newer build that only *added* fields) must still parse
        let path = tmp_path("unknown-fields");
        let entries = sample_entries();
        save(&path, &entries).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let augmented: String = text
            .lines()
            .map(|l| {
                if l.starts_with('#') {
                    l.to_string()
                } else {
                    format!("{l}\tfuture_field=whatever\tanother=1")
                }
            })
            .collect::<Vec<_>>()
            .join("\n");
        std::fs::write(&path, augmented).unwrap();
        let loaded = load(&path);
        assert!(loaded.cold_reason.is_none(), "{:?}", loaded.cold_reason);
        assert_eq!(loaded.entries.len(), entries.len());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compact_shrinks_a_grown_store() {
        let path = tmp_path("compact");
        let entries = sample_entries();
        save(&path, &entries).unwrap();
        let full = count_records(&path);
        assert_eq!(full, entries.len());
        // keep one entry: the rewrite must shed the rest
        let keep: HashMap<_, _> =
            entries.iter().take(1).map(|(k, v)| (*k, v.clone())).collect();
        let (before, after) = compact(&path, &keep).unwrap();
        assert_eq!(before, full);
        assert_eq!(after, 1);
        assert!(count_records(&path) < full, "compacted file did not shrink");
        let reloaded = load(&path);
        assert!(reloaded.cold_reason.is_none());
        assert_eq!(reloaded.entries.len(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compact_counts_stale_schema_records_before_dropping_them() {
        // a cold-started old store still reports its size, and the
        // compaction drops its dead records wholesale
        let path = tmp_path("compact-stale");
        std::fs::write(
            &path,
            "#tvec-dse-cache v2\nk=0000000000000001\tst=err\tkind=legality\tmsg=a\n\
             k=0000000000000002\tst=err\tkind=legality\tmsg=b\n",
        )
        .unwrap();
        let (before, after) = compact(&path, &HashMap::new()).unwrap();
        assert_eq!(before, 2);
        assert_eq!(after, 0);
        assert_eq!(count_records(&path), 0);
        assert!(load(&path).cold_reason.is_none(), "compacted store must be current-schema");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn regions_codec_round_trips() {
        use crate::dse::evaluate::regions_tag;
        let r2 = |f| Some(RegionPump::resource(f));
        let t = |f| Some(RegionPump::new(f, PumpMode::Throughput));
        let b = |f| Some(RegionPump::new(f, PumpMode::BareFast));
        for r in [
            None,
            Some(vec![r2(2), r2(4), None, r2(2)]),
            Some(vec![None, r2(8)]),
            Some(vec![t(2), r2(2), b(4), None]),
        ] {
            assert_eq!(regions_dec(&regions_tag(&r)).unwrap(), r);
        }
        assert!(regions_dec("garbage").is_err());
        assert!(regions_dec("m:2,x").is_err());
        // v3-style bare factors carry no mode letter: invalid under v4
        assert!(regions_dec("m:2,4").is_err());
    }

    #[test]
    fn pump_codec_covers_every_mode() {
        for p in [
            None,
            Some((2, PumpMode::Resource)),
            Some((4, PumpMode::Throughput)),
            Some((2, PumpMode::BareFast)),
        ] {
            assert_eq!(pump_dec(&pump_enc(&p)).unwrap(), p);
        }
        assert!(pump_dec("x2").is_err());
    }

    #[test]
    fn truncated_or_corrupt_file_is_cold_start() {
        let path = tmp_path("corrupt");
        // a valid store, truncated mid-record
        let entries = sample_entries();
        save(&path, &entries).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        // cut mid-token inside the last record: "…\tst" without its '='
        let cut = text.rfind("\tst=").unwrap() + "\tst".len();
        std::fs::write(&path, &text[..cut]).unwrap();
        let loaded = load(&path);
        assert!(loaded.entries.is_empty(), "truncated store must not half-load");
        assert!(loaded.cold_reason.is_some());
        // outright garbage
        std::fs::write(&path, "not a cache at all\n").unwrap();
        let loaded = load(&path);
        assert!(loaded.entries.is_empty());
        assert!(loaded.cold_reason.is_some());
        // empty file
        std::fs::write(&path, "").unwrap();
        let loaded = load(&path);
        assert!(loaded.entries.is_empty());
        assert!(loaded.cold_reason.is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_file_is_silent_cold_start() {
        let loaded = load(&tmp_path("never-written"));
        assert!(loaded.entries.is_empty());
        assert!(loaded.cold_reason.is_none());
    }

    #[test]
    fn escaping_round_trips_hostile_strings() {
        for s in ["plain", "tabs\tand\nnewlines", "100%\r%25", "κλίμα ≠ ascii"] {
            assert_eq!(unescape(&escape(s)).unwrap(), s, "{s:?}");
        }
    }
}
