//! Search strategies over the candidate grid.
//!
//! * **Exhaustive** — evaluate every generated candidate (the grid is
//!   already legality-pruned, and evaluations are parallel + memoized,
//!   so this is affordable for the paper's applications);
//! * **Greedy** — coordinate-descent hill climbing from the original
//!   (unpumped, unreplicated) point: evaluate all single-dimension
//!   neighbours, move to the best-ranked one, repeat until no
//!   neighbour improves. Orders of magnitude fewer evaluations on
//!   large grids, at the risk of a local optimum.
//!
//! Both honour an early-cutoff **budget** (maximum candidate
//! evaluations); exhaustive search truncates the grid and records that
//! it did, so a capped sweep never silently reads as a full one.

use crate::coordinator::pipeline::BuildSpec;
use crate::hw::Device;

use super::evaluate::{Evaluation, Evaluator};
use super::pareto::{frontier, Objective};
use super::space::{generate, DesignPoint, SpaceOptions};

/// How to walk the space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    Exhaustive,
    Greedy,
}

/// One search problem: a base spec plus the workload size (flops) its
/// throughput axis is derived from.
pub struct SearchBase {
    pub spec: BuildSpec,
    pub flops: f64,
}

/// Search configuration.
#[derive(Clone, Debug)]
pub struct SearchConfig {
    pub strategy: Strategy,
    pub objective: Objective,
    /// Early cutoff: maximum candidate evaluations across all bases.
    /// The baseline sweep (unpumped candidates, which anchor the
    /// iso-constraints) is always evaluated in full, so `evaluated`
    /// can exceed a budget smaller than the baseline.
    pub budget: Option<usize>,
}

impl SearchConfig {
    pub fn exhaustive(objective: Objective) -> SearchConfig {
        SearchConfig { strategy: Strategy::Exhaustive, objective, budget: None }
    }

    pub fn greedy(objective: Objective) -> SearchConfig {
        SearchConfig { strategy: Strategy::Greedy, objective, budget: None }
    }
}

/// Outcome of one search run.
pub struct SearchOutcome {
    /// Every successful evaluation, in a deterministic order.
    pub evaluations: Vec<Evaluation>,
    /// The resource-vs-throughput Pareto frontier of the fitting points.
    pub frontier: Vec<Evaluation>,
    /// The best unpumped single-replica design (iso-constraint anchor).
    pub reference: Option<Evaluation>,
    /// The candidate the objective selects.
    pub chosen: Option<Evaluation>,
    /// Candidate evaluations issued (cache hits included).
    pub evaluated: usize,
    /// Candidates that failed to compile (illegal bindings etc.).
    pub infeasible: usize,
    /// True when the budget truncated the sweep.
    pub truncated: bool,
}

/// Number of search dimensions two points differ in.
fn differing_dims(a: &DesignPoint, b: &DesignPoint) -> usize {
    (a.vectorize != b.vectorize) as usize
        + (a.pump != b.pump) as usize
        + (a.replicas != b.replicas) as usize
        + (a.cl0_request_mhz != b.cl0_request_mhz) as usize
}

/// Run a search over one or more bases (e.g. a PE-count sweep supplies
/// one base per PE configuration; the frontier and selection span all
/// of them).
pub fn run_search(
    evaluator: &Evaluator,
    bases: &[SearchBase],
    device: &Device,
    opts: &SpaceOptions,
    cfg: &SearchConfig,
) -> Result<SearchOutcome, String> {
    if bases.is_empty() {
        return Err("search needs at least one base spec".into());
    }
    let mut evaluations: Vec<Evaluation> = Vec::new();
    let mut evaluated = 0usize;
    let mut infeasible = 0usize;
    let mut truncated = false;

    // one legality-pruned grid per base
    let grids: Vec<Vec<DesignPoint>> =
        bases.iter().map(|b| generate(&b.spec, device, opts)).collect();
    let is_baseline = |p: &DesignPoint| {
        p.pump.is_none() && p.replicas == 1 && p.cl0_request_mhz.is_none()
    };

    // Baseline sweep: every unpumped single-replica candidate (the
    // conventional designs). The best-throughput fitting one anchors
    // the iso-constraints — "iso-throughput" means not losing against
    // the best design traditional vectorization alone can reach.
    let mut reference: Option<Evaluation> = None;
    for (base, grid) in bases.iter().zip(&grids) {
        let baseline: Vec<DesignPoint> =
            grid.iter().filter(|p| is_baseline(p)).cloned().collect();
        evaluated += baseline.len();
        for r in evaluator.evaluate_all(&base.spec, &baseline, base.flops) {
            match r {
                Ok(e) => {
                    if e.fits
                        && reference.as_ref().map(|r| e.gops > r.gops).unwrap_or(true)
                    {
                        reference = Some(e.clone());
                    }
                    evaluations.push(e);
                }
                Err(_) => infeasible += 1,
            }
        }
    }
    let reference = match reference {
        Some(r) => r,
        None => return Err("no unpumped configuration fits the device".into()),
    };

    for (base, grid) in bases.iter().zip(&grids) {
        let full_grid: Vec<DesignPoint> = grid
            .iter()
            .filter(|p| **p != DesignPoint::original())
            .cloned()
            .collect();
        match cfg.strategy {
            Strategy::Exhaustive => {
                // the baseline points are already evaluated
                let mut batch: Vec<DesignPoint> = full_grid
                    .into_iter()
                    .filter(|p| !is_baseline(p))
                    .collect();
                if let Some(budget) = cfg.budget {
                    let remaining = budget.saturating_sub(evaluated);
                    if batch.len() > remaining {
                        batch.truncate(remaining);
                        truncated = true;
                    }
                }
                evaluated += batch.len();
                for r in evaluator.evaluate_all(&base.spec, &batch, base.flops) {
                    match r {
                        Ok(e) => evaluations.push(e),
                        Err(_) => infeasible += 1,
                    }
                }
            }
            Strategy::Greedy => {
                // the full grid (baseline included) so the climb can
                // route through unpumped intermediates; re-evaluations
                // are cache hits
                let (evs, stats) = greedy_climb(
                    evaluator,
                    base,
                    &full_grid,
                    &cfg.objective,
                    &reference,
                    cfg.budget.map(|b| b.saturating_sub(evaluated)),
                );
                evaluated += stats.0;
                infeasible += stats.1;
                truncated |= stats.2;
                evaluations.extend(evs);
            }
        }
    }

    let front = frontier(&evaluations);
    let chosen = cfg
        .objective
        .select(&evaluations, &reference)
        .cloned()
        // never pick something the reference dominates outright
        .filter(|c| {
            cfg.objective
                .rank(c, &reference)
                .le(&cfg.objective.rank(&reference, &reference))
        })
        .or_else(|| Some(reference.clone()));

    Ok(SearchOutcome {
        frontier: front,
        reference: Some(reference),
        chosen,
        evaluations,
        evaluated,
        infeasible,
        truncated,
    })
}

/// Coordinate-descent hill climb from the original point. Returns the
/// evaluations performed and (issued, infeasible, truncated).
fn greedy_climb(
    evaluator: &Evaluator,
    base: &SearchBase,
    grid: &[DesignPoint],
    objective: &Objective,
    reference: &Evaluation,
    budget: Option<usize>,
) -> (Vec<Evaluation>, (usize, usize, bool)) {
    let mut evaluations: Vec<Evaluation> = Vec::new();
    let mut issued = 0usize;
    let mut infeasible = 0usize;
    let mut truncated = false;
    let mut visited: Vec<bool> = vec![false; grid.len()];

    let mut current = DesignPoint::original();
    let mut current_eval: Option<Evaluation> =
        evaluator.evaluate(&base.spec, &current, base.flops).ok();
    loop {
        let neighbour_idx: Vec<usize> = grid
            .iter()
            .enumerate()
            .filter(|&(i, p)| !visited[i] && differing_dims(p, &current) == 1)
            .map(|(i, _)| i)
            .collect();
        if neighbour_idx.is_empty() {
            break;
        }
        let mut batch: Vec<DesignPoint> = Vec::new();
        for &i in &neighbour_idx {
            if let Some(b) = budget {
                if issued >= b {
                    truncated = true;
                    break;
                }
            }
            visited[i] = true;
            batch.push(grid[i].clone());
            issued += 1;
        }
        if batch.is_empty() {
            break;
        }
        let mut best_step: Option<Evaluation> = None;
        for r in evaluator.evaluate_all(&base.spec, &batch, base.flops) {
            match r {
                Ok(e) => {
                    let better = best_step
                        .as_ref()
                        .map(|b| objective.rank(&e, reference) < objective.rank(b, reference))
                        .unwrap_or(true);
                    if better {
                        best_step = Some(e.clone());
                    }
                    evaluations.push(e);
                }
                Err(_) => infeasible += 1,
            }
        }
        let step = match best_step {
            Some(s) => s,
            None => break,
        };
        let improves = current_eval
            .as_ref()
            .map(|c| objective.rank(&step, reference) < objective.rank(c, reference))
            .unwrap_or(true);
        if !improves || truncated {
            break;
        }
        current = step.point.clone();
        current_eval = Some(step);
    }
    (evaluations, (issued, infeasible, truncated))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::coordinator::BuildSpec;
    use crate::ir::PumpMode;

    fn vecadd_bases() -> Vec<SearchBase> {
        let n = 1i64 << 14;
        vec![SearchBase {
            spec: BuildSpec::new(apps::vecadd::build()).bind("N", n).seeded(3),
            flops: apps::vecadd::flops(n),
        }]
    }

    fn small_opts() -> SpaceOptions {
        SpaceOptions {
            vector_widths: vec![2, 4, 8],
            pump_factors: vec![2, 4],
            pump_modes: vec![PumpMode::Resource],
            max_replicas: 1,
            cl0_requests_mhz: vec![],
        }
    }

    #[test]
    fn exhaustive_finds_pumped_optimum_for_vecadd() {
        let device = Device::u280();
        let ev = Evaluator::new();
        let out = run_search(
            &ev,
            &vecadd_bases(),
            &device,
            &small_opts(),
            &SearchConfig::exhaustive(Objective::resource()),
        )
        .unwrap();
        assert!(!out.frontier.is_empty());
        let chosen = out.chosen.as_ref().unwrap();
        assert_eq!(chosen.point.pump, Some((2, PumpMode::Resource)));
        assert_eq!(chosen.point.vectorize, Some(("vadd".into(), 8)));
        assert!(!out.truncated);
    }

    #[test]
    fn budget_cuts_off_early_and_is_recorded() {
        let device = Device::u280();
        let ev = Evaluator::new();
        let cfg = SearchConfig {
            strategy: Strategy::Exhaustive,
            objective: Objective::resource(),
            budget: Some(4),
        };
        let out =
            run_search(&ev, &vecadd_bases(), &device, &small_opts(), &cfg).unwrap();
        assert!(out.evaluated <= 4);
        assert!(out.truncated);
    }

    #[test]
    fn greedy_reaches_the_exhaustive_choice_on_vecadd() {
        let device = Device::u280();
        let opts = small_opts();
        let ex = run_search(
            &Evaluator::new(),
            &vecadd_bases(),
            &device,
            &opts,
            &SearchConfig::exhaustive(Objective::resource()),
        )
        .unwrap();
        let gr = run_search(
            &Evaluator::new(),
            &vecadd_bases(),
            &device,
            &opts,
            &SearchConfig::greedy(Objective::resource()),
        )
        .unwrap();
        let (ec, gc) = (ex.chosen.unwrap(), gr.chosen.unwrap());
        assert_eq!(ec.point, gc.point, "greedy diverged: {} vs {}", ec.label, gc.label);
    }

    #[test]
    fn repeated_search_is_fully_cached() {
        let device = Device::u280();
        let ev = Evaluator::new();
        let cfg = SearchConfig::exhaustive(Objective::resource());
        run_search(&ev, &vecadd_bases(), &device, &small_opts(), &cfg).unwrap();
        let misses_after_first = ev.cache_misses();
        run_search(&ev, &vecadd_bases(), &device, &small_opts(), &cfg).unwrap();
        assert_eq!(
            ev.cache_misses(),
            misses_after_first,
            "second sweep must be served from the cache"
        );
        assert!(ev.cache_hits() > 0);
    }
}
