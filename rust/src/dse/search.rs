//! Search strategies over the candidate grid.
//!
//! * **Exhaustive** — evaluate every generated candidate (the grid is
//!   already legality-pruned, and evaluations are parallel + memoized,
//!   so this is affordable for the paper's applications);
//! * **Greedy** — coordinate-descent hill climbing from the original
//!   (unpumped, unreplicated) point: evaluate all single-dimension
//!   neighbours, move to the best-ranked one, repeat until no
//!   neighbour improves. Orders of magnitude fewer evaluations on
//!   large grids, at the risk of a local optimum.
//! * **Anneal** — simulated annealing with a deterministic seeded RNG
//!   ([`crate::util::Rng`]): propose single-dimension moves (with an
//!   occasional random restart), accept uphill moves with probability
//!   `exp(-Δ/T)` under a geometric cooling schedule. Same seed ⇒ same
//!   walk ⇒ same chosen point.
//! * **Halving** — successive halving over the legality-pruned grid.
//!   The fidelity axis is the number of P&R jitter seeds averaged per
//!   candidate: round 0 scores every candidate under the base seed,
//!   each later round re-prices the surviving half under one more seed
//!   and ranks by mean energy, so survivors are configurations that
//!   are good *robustly*, not by one lucky timing draw.
//!
//! All strategies honour an early-cutoff **budget** (maximum candidate
//! evaluations); budget truncation is recorded, so a capped sweep never
//! silently reads as a full one. All are memo-backed — re-evaluations
//! (and repeated invocations through a persistent cache directory) are
//! cache hits.

use std::collections::HashMap;

use crate::coordinator::pipeline::BuildSpec;
use crate::hw::Device;
use crate::util::Rng;

use super::evaluate::{EvalError, Evaluation, Evaluator, FailKind};
use super::pareto::{frontier, Objective};
use super::space::{generate, DesignPoint, SpaceOptions};

/// How to walk the space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    Exhaustive,
    Greedy,
    Anneal,
    Halving,
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Exhaustive => "exhaustive",
            Strategy::Greedy => "greedy",
            Strategy::Anneal => "anneal",
            Strategy::Halving => "halving",
        }
    }

    /// Parse a CLI strategy name.
    pub fn from_name(name: &str) -> Option<Strategy> {
        match name {
            "exhaustive" => Some(Strategy::Exhaustive),
            "greedy" => Some(Strategy::Greedy),
            "anneal" => Some(Strategy::Anneal),
            "halving" => Some(Strategy::Halving),
            _ => None,
        }
    }
}

/// One search problem: a base spec plus the workload size (flops) its
/// throughput axis is derived from.
pub struct SearchBase {
    pub spec: BuildSpec,
    pub flops: f64,
}

/// Search configuration.
#[derive(Clone, Debug)]
pub struct SearchConfig {
    pub strategy: Strategy,
    pub objective: Objective,
    /// Early cutoff: maximum candidate evaluations across all bases.
    /// The baseline sweep (unpumped candidates, which anchor the
    /// iso-constraints) is always evaluated in full, so `evaluated`
    /// can exceed a budget smaller than the baseline.
    pub budget: Option<usize>,
    /// Seed for the stochastic strategies (anneal's walk, halving's
    /// sampling order). Deterministic: same seed ⇒ same outcome.
    pub seed: u64,
}

impl SearchConfig {
    pub fn exhaustive(objective: Objective) -> SearchConfig {
        SearchConfig { strategy: Strategy::Exhaustive, objective, budget: None, seed: 1 }
    }

    pub fn greedy(objective: Objective) -> SearchConfig {
        SearchConfig { strategy: Strategy::Greedy, objective, budget: None, seed: 1 }
    }

    pub fn anneal(objective: Objective) -> SearchConfig {
        SearchConfig { strategy: Strategy::Anneal, objective, budget: None, seed: 1 }
    }

    pub fn halving(objective: Objective) -> SearchConfig {
        SearchConfig { strategy: Strategy::Halving, objective, budget: None, seed: 1 }
    }

    pub fn with_seed(mut self, seed: u64) -> SearchConfig {
        self.seed = seed;
        self
    }
}

/// Outcome of one search run.
pub struct SearchOutcome {
    /// Every successful evaluation, in a deterministic order.
    pub evaluations: Vec<Evaluation>,
    /// The resource-vs-throughput Pareto frontier of the fitting points.
    pub frontier: Vec<Evaluation>,
    /// The best unpumped single-replica design (iso-constraint anchor).
    pub reference: Option<Evaluation>,
    /// The candidate the objective selects.
    pub chosen: Option<Evaluation>,
    /// Candidate evaluations issued (cache hits included).
    pub evaluated: usize,
    /// Candidates rejected by a legality check (expected pruning).
    pub illegal: usize,
    /// Candidates that failed with a genuine compile error.
    pub compile_failed: usize,
    /// True when the budget truncated the sweep.
    pub truncated: bool,
}

impl SearchOutcome {
    /// Total candidates that did not evaluate, either kind.
    pub fn infeasible(&self) -> usize {
        self.illegal + self.compile_failed
    }
}

/// Per-strategy bookkeeping: evaluations issued and failures by kind.
#[derive(Default)]
struct WalkStats {
    issued: usize,
    illegal: usize,
    compile_failed: usize,
    truncated: bool,
}

impl WalkStats {
    fn count_failure(&mut self, e: &EvalError) {
        match e.kind {
            FailKind::Legality => self.illegal += 1,
            FailKind::Compile => self.compile_failed += 1,
        }
    }
}

/// Number of search dimensions two points differ in.
fn differing_dims(a: &DesignPoint, b: &DesignPoint) -> usize {
    (a.vectorize != b.vectorize) as usize
        + (a.pump != b.pump) as usize
        + (a.replicas != b.replicas) as usize
        + (a.cl0_request_mhz != b.cl0_request_mhz) as usize
}

/// Scalar energy for the stochastic strategies (lower is better):
/// the objective's rank metric, with an offset that keeps every
/// infeasible point above every feasible one.
fn energy(objective: &Objective, e: &Evaluation, reference: &Evaluation) -> f64 {
    let (class, metric) = objective.rank(e, reference);
    metric + class as f64 * 1e9
}

/// Run a search over one or more bases (e.g. a PE-count sweep supplies
/// one base per PE configuration; the frontier and selection span all
/// of them).
pub fn run_search(
    evaluator: &Evaluator,
    bases: &[SearchBase],
    device: &Device,
    opts: &SpaceOptions,
    cfg: &SearchConfig,
) -> Result<SearchOutcome, String> {
    if bases.is_empty() {
        return Err("search needs at least one base spec".into());
    }
    let mut evaluations: Vec<Evaluation> = Vec::new();
    let mut evaluated = 0usize;
    let mut illegal = 0usize;
    let mut compile_failed = 0usize;
    let mut truncated = false;
    // candidates the stochastic strategies endorse over the plain
    // rank-selection (halving's robust winner)
    let mut winners: Vec<Evaluation> = Vec::new();

    // one legality-pruned grid per base
    let grids: Vec<Vec<DesignPoint>> =
        bases.iter().map(|b| generate(&b.spec, device, opts)).collect();
    let is_baseline = |p: &DesignPoint| {
        p.pump.is_none() && p.replicas == 1 && p.cl0_request_mhz.is_none()
    };

    // Baseline sweep: every unpumped single-replica candidate (the
    // conventional designs). The best-throughput fitting one anchors
    // the iso-constraints — "iso-throughput" means not losing against
    // the best design traditional vectorization alone can reach.
    let mut reference: Option<Evaluation> = None;
    for (i, (base, grid)) in bases.iter().zip(&grids).enumerate() {
        let baseline: Vec<DesignPoint> =
            grid.iter().filter(|p| is_baseline(p)).cloned().collect();
        evaluated += baseline.len();
        for r in evaluator.evaluate_all(&base.spec, &baseline, base.flops) {
            match r {
                Ok(mut e) => {
                    e.base = i;
                    if e.fits
                        && reference.as_ref().map(|r| e.gops > r.gops).unwrap_or(true)
                    {
                        reference = Some(e.clone());
                    }
                    evaluations.push(e);
                }
                Err(err) => match err.kind {
                    FailKind::Legality => illegal += 1,
                    FailKind::Compile => compile_failed += 1,
                },
            }
        }
    }
    let reference = match reference {
        Some(r) => r,
        None => return Err("no unpumped configuration fits the device".into()),
    };

    for (i, (base, grid)) in bases.iter().zip(&grids).enumerate() {
        let full_grid: Vec<DesignPoint> = grid
            .iter()
            .filter(|p| **p != DesignPoint::original())
            .cloned()
            .collect();
        let remaining_budget = cfg.budget.map(|b| b.saturating_sub(evaluated));
        let (mut evs, winner, stats) = match cfg.strategy {
            Strategy::Exhaustive => {
                // the baseline points are already evaluated
                let mut stats = WalkStats::default();
                let mut batch: Vec<DesignPoint> = full_grid
                    .into_iter()
                    .filter(|p| !is_baseline(p))
                    .collect();
                if let Some(remaining) = remaining_budget {
                    if batch.len() > remaining {
                        batch.truncate(remaining);
                        stats.truncated = true;
                    }
                }
                stats.issued = batch.len();
                let mut evs = Vec::new();
                for r in evaluator.evaluate_all(&base.spec, &batch, base.flops) {
                    match r {
                        Ok(e) => evs.push(e),
                        Err(err) => stats.count_failure(&err),
                    }
                }
                (evs, None, stats)
            }
            Strategy::Greedy => {
                // the full grid (baseline included) so the climb can
                // route through unpumped intermediates; re-evaluations
                // are cache hits
                greedy_climb(
                    evaluator,
                    base,
                    &full_grid,
                    &cfg.objective,
                    &reference,
                    remaining_budget,
                )
            }
            Strategy::Anneal => anneal_walk(
                evaluator,
                base,
                &full_grid,
                &cfg.objective,
                &reference,
                remaining_budget,
                cfg.seed.wrapping_add(i as u64),
            ),
            Strategy::Halving => halving_rounds(
                evaluator,
                base,
                &full_grid,
                &cfg.objective,
                &reference,
                remaining_budget,
                cfg.seed.wrapping_add(i as u64),
            ),
        };
        for e in &mut evs {
            e.base = i;
        }
        evaluated += stats.issued;
        illegal += stats.illegal;
        compile_failed += stats.compile_failed;
        truncated |= stats.truncated;
        evaluations.extend(evs);
        if let Some(mut w) = winner {
            w.base = i;
            winners.push(w);
        }
    }

    let front = frontier(&evaluations);
    // never pick something the reference dominates outright
    let beats_reference = |c: &Evaluation| {
        cfg.objective
            .rank(c, &reference)
            .le(&cfg.objective.rank(&reference, &reference))
    };
    // the stochastic strategies may endorse a specific winner (e.g.
    // halving's robust multi-seed choice); a dominated endorsement
    // falls back to rank-selection over everything evaluated, not
    // straight to the reference
    let endorsed = winners
        .into_iter()
        .filter(|w| cfg.objective.feasible(w, &reference))
        .min_by(|a, b| {
            let (ra, rb) = (cfg.objective.rank(a, &reference), cfg.objective.rank(b, &reference));
            ra.0.cmp(&rb.0)
                .then(ra.1.partial_cmp(&rb.1).unwrap_or(std::cmp::Ordering::Equal))
                .then(a.label.cmp(&b.label))
        });
    let chosen = endorsed
        .filter(|c| beats_reference(c))
        .or_else(|| {
            cfg.objective
                .select(&evaluations, &reference)
                .cloned()
                .filter(|c| beats_reference(c))
        })
        .or_else(|| Some(reference.clone()));

    Ok(SearchOutcome {
        frontier: front,
        reference: Some(reference),
        chosen,
        evaluations,
        evaluated,
        illegal,
        compile_failed,
        truncated,
    })
}

/// Coordinate-descent hill climb from the original point.
fn greedy_climb(
    evaluator: &Evaluator,
    base: &SearchBase,
    grid: &[DesignPoint],
    objective: &Objective,
    reference: &Evaluation,
    budget: Option<usize>,
) -> (Vec<Evaluation>, Option<Evaluation>, WalkStats) {
    let mut evaluations: Vec<Evaluation> = Vec::new();
    let mut stats = WalkStats::default();
    let mut visited: Vec<bool> = vec![false; grid.len()];

    let mut current = DesignPoint::original();
    let mut current_eval: Option<Evaluation> =
        evaluator.evaluate(&base.spec, &current, base.flops).ok();
    loop {
        let neighbour_idx: Vec<usize> = grid
            .iter()
            .enumerate()
            .filter(|&(i, p)| !visited[i] && differing_dims(p, &current) == 1)
            .map(|(i, _)| i)
            .collect();
        if neighbour_idx.is_empty() {
            break;
        }
        let mut batch: Vec<DesignPoint> = Vec::new();
        for &i in &neighbour_idx {
            if let Some(b) = budget {
                if stats.issued >= b {
                    stats.truncated = true;
                    break;
                }
            }
            visited[i] = true;
            batch.push(grid[i].clone());
            stats.issued += 1;
        }
        if batch.is_empty() {
            break;
        }
        let mut best_step: Option<Evaluation> = None;
        for r in evaluator.evaluate_all(&base.spec, &batch, base.flops) {
            match r {
                Ok(e) => {
                    let better = best_step
                        .as_ref()
                        .map(|b| objective.rank(&e, reference) < objective.rank(b, reference))
                        .unwrap_or(true);
                    if better {
                        best_step = Some(e.clone());
                    }
                    evaluations.push(e);
                }
                Err(err) => stats.count_failure(&err),
            }
        }
        let step = match best_step {
            Some(s) => s,
            None => break,
        };
        let improves = current_eval
            .as_ref()
            .map(|c| objective.rank(&step, reference) < objective.rank(c, reference))
            .unwrap_or(true);
        if !improves || stats.truncated {
            break;
        }
        current = step.point.clone();
        current_eval = Some(step);
    }
    (evaluations, None, stats)
}

/// Simulated annealing over the grid. Deterministic for a fixed seed:
/// proposals come from a seeded [`Rng`], the schedule is geometric, and
/// evaluations are pure, so the whole walk replays identically.
fn anneal_walk(
    evaluator: &Evaluator,
    base: &SearchBase,
    grid: &[DesignPoint],
    objective: &Objective,
    reference: &Evaluation,
    budget: Option<usize>,
    seed: u64,
) -> (Vec<Evaluation>, Option<Evaluation>, WalkStats) {
    let mut stats = WalkStats::default();
    if grid.is_empty() {
        return (Vec::new(), None, stats);
    }
    let mut rng = Rng::new(seed ^ 0xa95ea1);
    let default_iters = (grid.len() * 2).max(8);
    let iters = match budget {
        Some(b) => default_iters.min(b),
        None => default_iters,
    };
    if iters < default_iters {
        stats.truncated = true;
    }

    let mut evaluations: Vec<Evaluation> = Vec::new();
    let mut visited: Vec<bool> = vec![false; grid.len()];

    // start at the original (already priced in the baseline sweep)
    let mut current = DesignPoint::original();
    let mut current_energy = evaluator
        .evaluate(&base.spec, &current, base.flops)
        .ok()
        .map(|e| energy(objective, &e, reference))
        .unwrap_or(f64::INFINITY);

    let t0 = 0.5f64;
    let t_end = 1e-3f64;
    for step in 0..iters {
        let frac = step as f64 / iters.max(1) as f64;
        let t = t0 * (t_end / t0).powf(frac);

        // Propose: a 1-dimension neighbour, or (15 %) a random jump.
        // Unvisited points are preferred in both branches — the walk is
        // coverage-biased, so a full-length run on a grid that fits the
        // iteration count provably prices every candidate (and the best
        // tracker then equals the exhaustive optimum).
        let neighbours: Vec<usize> = grid
            .iter()
            .enumerate()
            .filter(|(i, p)| !visited[*i] && differing_dims(p, &current) == 1)
            .map(|(i, _)| i)
            .collect();
        let jump = neighbours.is_empty() || rng.f64() < 0.15;
        let cand_idx = if !jump {
            neighbours[rng.range(0, neighbours.len())]
        } else {
            let unvisited: Vec<usize> =
                (0..grid.len()).filter(|&i| !visited[i]).collect();
            if unvisited.is_empty() {
                // fully covered: keep refining among visited neighbours
                let revisitable: Vec<usize> = grid
                    .iter()
                    .enumerate()
                    .filter(|(_, p)| differing_dims(p, &current) == 1)
                    .map(|(i, _)| i)
                    .collect();
                if revisitable.is_empty() {
                    rng.range(0, grid.len())
                } else {
                    revisitable[rng.range(0, revisitable.len())]
                }
            } else {
                unvisited[rng.range(0, unvisited.len())]
            }
        };
        let first_visit = !visited[cand_idx];
        visited[cand_idx] = true;

        stats.issued += 1;
        match evaluator.evaluate(&base.spec, &grid[cand_idx], base.flops) {
            Ok(e) => {
                let cand_energy = energy(objective, &e, reference);
                if first_visit {
                    evaluations.push(e.clone());
                }
                let d = cand_energy - current_energy;
                if d <= 0.0 || rng.f64() < (-d / t).exp() {
                    current = grid[cand_idx].clone();
                    current_energy = cand_energy;
                }
            }
            Err(err) => stats.count_failure(&err),
        }
    }
    // No endorsed winner: everything the walk priced is in
    // `evaluations`, and `run_search`'s rank-selection additionally
    // sees the baseline sweep — a subset endorsement could only tie or
    // lose against it. (Halving *does* endorse, because its multi-seed
    // mean deliberately overrides the single-seed rank.)
    (evaluations, None, stats)
}

/// Successive halving. Fidelity = number of P&R jitter seeds averaged:
/// every survivor of round *r* has been priced under `r + 1` seeds and
/// is ranked by its mean energy, so the final winner is robust to
/// timing jitter rather than lucky under one draw. The budget is spent
/// half on the opening full-grid round, half on the refinement rounds.
fn halving_rounds(
    evaluator: &Evaluator,
    base: &SearchBase,
    grid: &[DesignPoint],
    objective: &Objective,
    reference: &Evaluation,
    budget: Option<usize>,
    seed: u64,
) -> (Vec<Evaluation>, Option<Evaluation>, WalkStats) {
    let mut stats = WalkStats::default();
    if grid.is_empty() {
        return (Vec::new(), None, stats);
    }
    // deterministic sampling order, so a budget-truncated opening round
    // is an unbiased sample rather than a prefix artifact
    let mut order: Vec<usize> = (0..grid.len()).collect();
    Rng::new(seed ^ 0x4a1f).shuffle(&mut order);

    let mut survivors: Vec<usize> = order;
    if let Some(b) = budget {
        let opening = (b / 2).max(1).min(survivors.len());
        if opening < survivors.len() {
            survivors.truncate(opening);
            stats.truncated = true;
        }
    }

    let mut evaluations: Vec<Evaluation> = Vec::new();
    // candidate index → (energy sum, samples, base-seed evaluation)
    let mut scores: HashMap<usize, (f64, u32, Option<Evaluation>)> = HashMap::new();
    let mut remaining = budget;

    let max_rounds = 4usize;
    for round in 0..max_rounds {
        if survivors.is_empty() {
            break;
        }
        if let Some(rem) = remaining {
            if rem == 0 {
                stats.truncated = true;
                break;
            }
            if survivors.len() > rem {
                survivors.truncate(rem);
                stats.truncated = true;
            }
        }
        // round 0 prices under the base seed (sharing cache entries
        // with every other strategy); later rounds add jitter seeds
        let spec_r = if round == 0 {
            base.spec.clone()
        } else {
            let s = base.spec.seed.wrapping_add(round as u64);
            base.spec.clone().seeded(s)
        };
        let points: Vec<DesignPoint> = survivors.iter().map(|&i| grid[i].clone()).collect();
        stats.issued += points.len();
        if let Some(rem) = remaining.as_mut() {
            *rem = rem.saturating_sub(points.len());
        }
        let results = evaluator.evaluate_all(&spec_r, &points, base.flops);
        let mut alive: Vec<usize> = Vec::new();
        for (&idx, r) in survivors.iter().zip(&results) {
            match r {
                Ok(e) => {
                    let en = energy(objective, e, reference);
                    let slot = scores.entry(idx).or_insert((0.0, 0, None));
                    slot.0 += en;
                    slot.1 += 1;
                    if round == 0 {
                        slot.2 = Some(e.clone());
                        evaluations.push(e.clone());
                    }
                    alive.push(idx);
                }
                Err(err) => stats.count_failure(err),
            }
        }
        // rank by mean energy, keep the better half
        alive.sort_by(|a, b| {
            let ma = scores[a].0 / scores[a].1 as f64;
            let mb = scores[b].0 / scores[b].1 as f64;
            ma.partial_cmp(&mb)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(b))
        });
        if alive.len() <= 2 {
            survivors = alive;
            break;
        }
        alive.truncate((alive.len() + 1) / 2);
        survivors = alive;
    }

    // winner: the surviving candidate with the best mean energy,
    // reported through its base-seed evaluation
    let winner = survivors
        .iter()
        .filter_map(|i| {
            let (sum, n, ev) = scores.get(i)?;
            ev.clone().map(|e| (sum / *n as f64, e))
        })
        .min_by(|(a, _), (b, _)| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(_, e)| e);
    (evaluations, winner, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::coordinator::BuildSpec;
    use crate::ir::PumpMode;

    fn vecadd_bases() -> Vec<SearchBase> {
        let n = 1i64 << 14;
        vec![SearchBase {
            spec: BuildSpec::new(apps::vecadd::build()).bind("N", n).seeded(3),
            flops: apps::vecadd::flops(n),
        }]
    }

    fn small_opts() -> SpaceOptions {
        SpaceOptions {
            vector_widths: vec![2, 4, 8],
            pump_factors: vec![2, 4],
            pump_modes: vec![PumpMode::Resource],
            max_replicas: 1,
            cl0_requests_mhz: vec![],
        }
    }

    #[test]
    fn exhaustive_finds_pumped_optimum_for_vecadd() {
        let device = Device::u280();
        let ev = Evaluator::new();
        let out = run_search(
            &ev,
            &vecadd_bases(),
            &device,
            &small_opts(),
            &SearchConfig::exhaustive(Objective::resource()),
        )
        .unwrap();
        assert!(!out.frontier.is_empty());
        let chosen = out.chosen.as_ref().unwrap();
        assert_eq!(chosen.point.pump, Some((2, PumpMode::Resource)));
        assert_eq!(chosen.point.vectorize, Some(("vadd".into(), 8)));
        assert!(!out.truncated);
    }

    #[test]
    fn budget_cuts_off_early_and_is_recorded() {
        let device = Device::u280();
        let ev = Evaluator::new();
        let cfg = SearchConfig {
            strategy: Strategy::Exhaustive,
            objective: Objective::resource(),
            budget: Some(4),
            seed: 1,
        };
        let out =
            run_search(&ev, &vecadd_bases(), &device, &small_opts(), &cfg).unwrap();
        assert!(out.evaluated <= 4);
        assert!(out.truncated);
    }

    #[test]
    fn greedy_reaches_the_exhaustive_choice_on_vecadd() {
        let device = Device::u280();
        let opts = small_opts();
        let ex = run_search(
            &Evaluator::new(),
            &vecadd_bases(),
            &device,
            &opts,
            &SearchConfig::exhaustive(Objective::resource()),
        )
        .unwrap();
        let gr = run_search(
            &Evaluator::new(),
            &vecadd_bases(),
            &device,
            &opts,
            &SearchConfig::greedy(Objective::resource()),
        )
        .unwrap();
        let (ec, gc) = (ex.chosen.unwrap(), gr.chosen.unwrap());
        assert_eq!(ec.point, gc.point, "greedy diverged: {} vs {}", ec.label, gc.label);
    }

    #[test]
    fn anneal_reaches_the_exhaustive_choice_on_vecadd() {
        // the vecadd space is small: a full-length annealing walk must
        // find the same optimum the exhaustive sweep proves is best
        let device = Device::u280();
        let opts = small_opts();
        let ex = run_search(
            &Evaluator::new(),
            &vecadd_bases(),
            &device,
            &opts,
            &SearchConfig::exhaustive(Objective::resource()),
        )
        .unwrap();
        let an = run_search(
            &Evaluator::new(),
            &vecadd_bases(),
            &device,
            &opts,
            &SearchConfig::anneal(Objective::resource()).with_seed(42),
        )
        .unwrap();
        let (ec, ac) = (ex.chosen.unwrap(), an.chosen.unwrap());
        assert_eq!(ec.point, ac.point, "anneal diverged: {} vs {}", ec.label, ac.label);
    }

    #[test]
    fn anneal_is_deterministic_for_a_seed() {
        let device = Device::u280();
        let opts = small_opts();
        let run = |seed: u64| {
            let out = run_search(
                &Evaluator::new(),
                &vecadd_bases(),
                &device,
                &opts,
                &SearchConfig::anneal(Objective::resource()).with_seed(seed),
            )
            .unwrap();
            (
                out.chosen.unwrap().point,
                out.evaluated,
                out.evaluations.iter().map(|e| e.label.clone()).collect::<Vec<_>>(),
            )
        };
        let (p1, n1, l1) = run(7);
        let (p2, n2, l2) = run(7);
        assert_eq!(p1, p2, "same seed must choose the same point");
        assert_eq!(n1, n2, "same seed must issue the same evaluation count");
        assert_eq!(l1, l2, "same seed must walk the same path");
    }

    #[test]
    fn anneal_respects_budget() {
        let device = Device::u280();
        let cfg = SearchConfig {
            strategy: Strategy::Anneal,
            objective: Objective::resource(),
            budget: Some(10),
            seed: 5,
        };
        let out =
            run_search(&Evaluator::new(), &vecadd_bases(), &device, &small_opts(), &cfg)
                .unwrap();
        assert!(out.evaluated <= 10 + 4, "baseline + ≤ budget proposals");
        // a budgeted anneal still returns something sane
        let chosen = out.chosen.unwrap();
        let reference = out.reference.unwrap();
        assert!(chosen.resource_score <= reference.resource_score + 1e-12);
    }

    #[test]
    fn halving_reaches_the_exhaustive_choice_on_vecadd() {
        let device = Device::u280();
        let opts = small_opts();
        let ex = run_search(
            &Evaluator::new(),
            &vecadd_bases(),
            &device,
            &opts,
            &SearchConfig::exhaustive(Objective::resource()),
        )
        .unwrap();
        let ha = run_search(
            &Evaluator::new(),
            &vecadd_bases(),
            &device,
            &opts,
            &SearchConfig::halving(Objective::resource()).with_seed(11),
        )
        .unwrap();
        let (ec, hc) = (ex.chosen.unwrap(), ha.chosen.unwrap());
        assert_eq!(ec.point, hc.point, "halving diverged: {} vs {}", ec.label, hc.label);
    }

    #[test]
    fn halving_budget_samples_instead_of_full_grid() {
        let device = Device::u280();
        let cfg = SearchConfig {
            strategy: Strategy::Halving,
            objective: Objective::resource(),
            budget: Some(8),
            seed: 2,
        };
        let out =
            run_search(&Evaluator::new(), &vecadd_bases(), &device, &small_opts(), &cfg)
                .unwrap();
        assert!(out.truncated, "a tight budget must be recorded as truncation");
        assert!(out.chosen.is_some());
    }

    #[test]
    fn repeated_search_is_fully_cached() {
        let device = Device::u280();
        let ev = Evaluator::new();
        let cfg = SearchConfig::exhaustive(Objective::resource());
        run_search(&ev, &vecadd_bases(), &device, &small_opts(), &cfg).unwrap();
        let misses_after_first = ev.cache_misses();
        run_search(&ev, &vecadd_bases(), &device, &small_opts(), &cfg).unwrap();
        assert_eq!(
            ev.cache_misses(),
            misses_after_first,
            "second sweep must be served from the cache"
        );
        assert!(ev.cache_hits() > 0);
    }

    #[test]
    fn strategy_names_round_trip() {
        for s in [Strategy::Exhaustive, Strategy::Greedy, Strategy::Anneal, Strategy::Halving] {
            assert_eq!(Strategy::from_name(s.name()), Some(s));
        }
        assert_eq!(Strategy::from_name("nonsense"), None);
    }
}
