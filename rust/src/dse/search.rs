//! Search strategies over the candidate grid.
//!
//! * **Exhaustive** — evaluate every generated candidate (the grid is
//!   already legality-pruned, and evaluations are parallel + memoized,
//!   so this is affordable for the paper's applications);
//! * **Greedy** — coordinate-descent hill climbing from the original
//!   (unpumped, unreplicated) point: evaluate all single-dimension
//!   neighbours, move to the best-ranked one, repeat until no
//!   neighbour improves. Orders of magnitude fewer evaluations on
//!   large grids, at the risk of a local optimum.
//! * **Anneal** — simulated annealing with a deterministic seeded RNG
//!   ([`crate::util::Rng`]): propose single-dimension moves (with an
//!   occasional random restart), accept uphill moves with probability
//!   `exp(-Δ/T)` under a geometric cooling schedule. Same seed ⇒ same
//!   walk ⇒ same chosen point.
//! * **Halving** — successive halving over the legality-pruned grid.
//!   The fidelity axis is the number of P&R jitter seeds averaged per
//!   candidate: round 0 scores every candidate under the base seed,
//!   each later round re-prices the surviving half under one more seed
//!   and ranks by mean energy, so survivors are configurations that
//!   are good *robustly*, not by one lucky timing draw.
//!
//! All strategies honour an early-cutoff **budget** (maximum candidate
//! evaluations); budget truncation is recorded, so a capped sweep never
//! silently reads as a full one. All are memo-backed — re-evaluations
//! (and repeated invocations through a persistent cache directory) are
//! cache hits.

use std::collections::HashMap;

use crate::coordinator::pipeline::BuildSpec;
use crate::hw::Device;
use crate::util::Rng;

use super::evaluate::{EvalError, Evaluation, Evaluator, FailKind};
use super::pareto::{finite_metrics, frontier, Objective};
use super::space::{generate, DesignPoint, SpaceOptions};

/// How to walk the space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    Exhaustive,
    Greedy,
    Anneal,
    Halving,
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Exhaustive => "exhaustive",
            Strategy::Greedy => "greedy",
            Strategy::Anneal => "anneal",
            Strategy::Halving => "halving",
        }
    }

    /// Parse a CLI strategy name.
    pub fn from_name(name: &str) -> Option<Strategy> {
        match name {
            "exhaustive" => Some(Strategy::Exhaustive),
            "greedy" => Some(Strategy::Greedy),
            "anneal" => Some(Strategy::Anneal),
            "halving" => Some(Strategy::Halving),
            _ => None,
        }
    }
}

/// One search problem: a base spec plus the workload size (flops) its
/// throughput axis is derived from.
pub struct SearchBase {
    pub spec: BuildSpec,
    pub flops: f64,
}

/// Search configuration.
#[derive(Clone, Debug)]
pub struct SearchConfig {
    pub strategy: Strategy,
    pub objective: Objective,
    /// Early cutoff: maximum *new compiles* across all bases. Memo and
    /// disk-cache hits are free — a warm cache therefore explores at
    /// least as many points as a cold one under the same budget (it
    /// used to be charged per evaluation, so a fully warm cache could
    /// exhaust the budget while compiling nothing). The baseline sweep
    /// (unpumped candidates, which anchor the iso-constraints) is
    /// always evaluated in full, so its compiles can exceed a budget
    /// smaller than the baseline.
    pub budget: Option<usize>,
    /// Seed for the stochastic strategies (anneal's walk, halving's
    /// sampling order). Deterministic: same seed ⇒ same outcome.
    pub seed: u64,
    /// Per-candidate wall-clock budget in milliseconds. A candidate
    /// whose evaluation runs past this (wedged simulation, pathological
    /// compile) is reaped as `FailKind::Timeout` and quarantined —
    /// never retried within the run. `None` leaves the wall unarmed.
    pub deadline_ms: Option<u64>,
    /// Per-candidate slow-cycle budget for exact simulation during
    /// frontier verification. `None` keeps the built-in
    /// [`super::verify::MAX_VERIFY_CYCLES`] ceiling.
    pub sim_cycle_budget: Option<u64>,
}

impl SearchConfig {
    pub fn exhaustive(objective: Objective) -> SearchConfig {
        SearchConfig {
            strategy: Strategy::Exhaustive,
            objective,
            budget: None,
            seed: 1,
            deadline_ms: None,
            sim_cycle_budget: None,
        }
    }

    pub fn greedy(objective: Objective) -> SearchConfig {
        SearchConfig { strategy: Strategy::Greedy, ..SearchConfig::exhaustive(objective) }
    }

    pub fn anneal(objective: Objective) -> SearchConfig {
        SearchConfig { strategy: Strategy::Anneal, ..SearchConfig::exhaustive(objective) }
    }

    pub fn halving(objective: Objective) -> SearchConfig {
        SearchConfig { strategy: Strategy::Halving, ..SearchConfig::exhaustive(objective) }
    }

    pub fn with_seed(mut self, seed: u64) -> SearchConfig {
        self.seed = seed;
        self
    }

    /// Arm the per-candidate budgets (wall milliseconds, slow cycles).
    pub fn with_limits(
        mut self,
        deadline_ms: Option<u64>,
        sim_cycle_budget: Option<u64>,
    ) -> SearchConfig {
        self.deadline_ms = deadline_ms;
        self.sim_cycle_budget = sim_cycle_budget;
        self
    }
}

/// Outcome of one search run.
pub struct SearchOutcome {
    /// Every successful evaluation, in a deterministic order.
    pub evaluations: Vec<Evaluation>,
    /// The resource-vs-throughput Pareto frontier of the fitting points.
    pub frontier: Vec<Evaluation>,
    /// The best unpumped single-replica design (iso-constraint anchor).
    pub reference: Option<Evaluation>,
    /// The candidate the objective selects.
    pub chosen: Option<Evaluation>,
    /// Candidate evaluations issued (cache hits included).
    pub evaluated: usize,
    /// Candidates rejected by a legality check (expected pruning).
    pub illegal: usize,
    /// Candidates that failed with a genuine compile error.
    pub compile_failed: usize,
    /// Candidates that compiled but were rejected by the static
    /// design-rule checker (would deadlock or wedge in simulation).
    pub checker_rejected: usize,
    /// Candidates whose evaluation panicked; caught, classified and
    /// quarantined by the supervision layer.
    pub panicked: usize,
    /// Candidates reaped by the per-candidate wall or cycle budget.
    pub timed_out: usize,
    /// True when the budget truncated the sweep.
    pub truncated: bool,
}

impl SearchOutcome {
    /// Total candidates that did not evaluate, any kind.
    pub fn infeasible(&self) -> usize {
        self.illegal + self.compile_failed + self.checker_rejected + self.panicked + self.timed_out
    }

    /// Candidates quarantined by the supervision layer (never retried
    /// within a run, never persisted to the disk cache).
    pub fn quarantined(&self) -> usize {
        self.panicked + self.timed_out
    }
}

/// Per-strategy bookkeeping: evaluations issued and failures by kind.
#[derive(Default)]
struct WalkStats {
    issued: usize,
    illegal: usize,
    compile_failed: usize,
    checker_rejected: usize,
    panicked: usize,
    timed_out: usize,
    truncated: bool,
}

impl WalkStats {
    fn count_failure(&mut self, e: &EvalError) {
        match e.kind {
            FailKind::Legality => self.illegal += 1,
            FailKind::Compile => self.compile_failed += 1,
            FailKind::Check => self.checker_rejected += 1,
            FailKind::Panic => self.panicked += 1,
            FailKind::Timeout => self.timed_out += 1,
        }
    }
}

/// Number of search dimensions two points differ in. Two mixed
/// assignments of equal length count their per-region differences —
/// an anneal proposal at distance 1 mutates exactly one region's
/// pump (its factor *or* its mode: `RegionPump` equality covers both,
/// so a same-factor mode flip is also a single step); a uniform↔mixed
/// move counts as one pump-axis step.
fn pump_dims(a: &DesignPoint, b: &DesignPoint) -> usize {
    match (&a.regions, &b.regions) {
        (Some(x), Some(y)) if x.len() == y.len() => {
            x.iter().zip(y).filter(|(p, q)| p != q).count()
        }
        (None, None) => (a.pump != b.pump) as usize,
        _ => 1,
    }
}

fn differing_dims(a: &DesignPoint, b: &DesignPoint) -> usize {
    (a.vectorize != b.vectorize) as usize
        + pump_dims(a, b)
        + (a.replicas != b.replicas) as usize
        + (a.cl0_request_mhz != b.cl0_request_mhz) as usize
}

/// Scalar energy for the stochastic strategies (lower is better):
/// the objective's rank metric, with an offset that keeps every
/// infeasible point above every feasible one. `None` for a candidate
/// whose metrics are non-finite — such a point can never become the
/// walk's current state (∞ − ∞ acceptance terms were undefined).
fn energy(objective: &Objective, e: &Evaluation, reference: &Evaluation) -> Option<f64> {
    let (class, metric) = objective.rank(e, reference);
    let en = metric + class as f64 * 1e9;
    en.is_finite().then_some(en)
}

/// Run a search over one or more bases (e.g. a PE-count sweep supplies
/// one base per PE configuration; the frontier and selection span all
/// of them).
pub fn run_search(
    evaluator: &Evaluator,
    bases: &[SearchBase],
    device: &Device,
    opts: &SpaceOptions,
    cfg: &SearchConfig,
) -> Result<SearchOutcome, String> {
    if bases.is_empty() {
        return Err("search needs at least one base spec".into());
    }
    // arm the per-candidate budgets for everything this run evaluates
    evaluator.set_limits(cfg.deadline_ms, cfg.sim_cycle_budget);
    let mut evaluations: Vec<Evaluation> = Vec::new();
    let mut evaluated = 0usize;
    let mut illegal = 0usize;
    let mut compile_failed = 0usize;
    let mut checker_rejected = 0usize;
    let mut panicked = 0usize;
    let mut timed_out = 0usize;
    let mut truncated = false;
    // candidates the stochastic strategies endorse over the plain
    // rank-selection (halving's robust winner)
    let mut winners: Vec<Evaluation> = Vec::new();

    // budget meters new compiles only: cache hits are free
    let misses_start = evaluator.cache_misses();

    // one legality-pruned grid per base
    let grids: Vec<Vec<DesignPoint>> =
        bases.iter().map(|b| generate(&b.spec, device, opts)).collect();
    let is_baseline = |p: &DesignPoint| {
        p.pump.is_none()
            && p.regions.is_none()
            && p.replicas == 1
            && p.cl0_request_mhz.is_none()
    };

    // Baseline sweep: every unpumped single-replica candidate (the
    // conventional designs). The best-throughput fitting one anchors
    // the iso-constraints — "iso-throughput" means not losing against
    // the best design traditional vectorization alone can reach.
    let mut baseline_sp = evaluator.probe().map(|r| r.span("dse.search.baseline"));
    let mut reference: Option<Evaluation> = None;
    for (i, (base, grid)) in bases.iter().zip(&grids).enumerate() {
        let baseline: Vec<DesignPoint> =
            grid.iter().filter(|p| is_baseline(p)).cloned().collect();
        evaluated += baseline.len();
        for r in evaluator.evaluate_all(&base.spec, &baseline, base.flops) {
            match r {
                Ok(mut e) => {
                    e.base = i;
                    if e.fits
                        && finite_metrics(&e)
                        && reference.as_ref().map(|r| e.gops > r.gops).unwrap_or(true)
                    {
                        reference = Some(e.clone());
                    }
                    evaluations.push(e);
                }
                Err(err) => match err.kind {
                    FailKind::Legality => illegal += 1,
                    FailKind::Compile => compile_failed += 1,
                    FailKind::Check => checker_rejected += 1,
                    FailKind::Panic => panicked += 1,
                    FailKind::Timeout => timed_out += 1,
                },
            }
        }
    }
    if let Some(s) = baseline_sp.as_mut() {
        s.note("evaluated", evaluated);
    }
    drop(baseline_sp);
    let reference = match reference {
        Some(r) => r,
        None => return Err("no unpumped configuration fits the device".into()),
    };

    for (i, (base, grid)) in bases.iter().zip(&grids).enumerate() {
        let full_grid: Vec<DesignPoint> = grid
            .iter()
            .filter(|p| **p != DesignPoint::original())
            .cloned()
            .collect();
        let compiles_so_far = evaluator.cache_misses() - misses_start;
        let remaining_budget = cfg.budget.map(|b| b.saturating_sub(compiles_so_far));
        let hits_before = evaluator.cache_hits();
        let misses_before = evaluator.cache_misses();
        let mut round_sp = evaluator
            .probe()
            .map(|r| r.span(&format!("dse.search.{}", cfg.strategy.name())));
        if let Some(s) = round_sp.as_mut() {
            s.note("base", i);
            s.note("grid", full_grid.len());
        }
        let (mut evs, winner, stats) = match cfg.strategy {
            Strategy::Exhaustive => {
                // the baseline points are already evaluated
                let mut stats = WalkStats::default();
                let mut batch: Vec<DesignPoint> = full_grid
                    .into_iter()
                    .filter(|p| !is_baseline(p))
                    .collect();
                if let Some(remaining) = remaining_budget {
                    // keep every cached point (free) and up to
                    // `remaining` uncached ones
                    let mut new_compiles = 0usize;
                    let mut kept = Vec::with_capacity(batch.len());
                    for p in batch {
                        if evaluator.contains(&base.spec, &p, base.flops) {
                            kept.push(p);
                            continue;
                        }
                        if new_compiles < remaining {
                            new_compiles += 1;
                            kept.push(p);
                        } else {
                            stats.truncated = true;
                        }
                    }
                    batch = kept;
                }
                stats.issued = batch.len();
                let mut evs = Vec::new();
                for r in evaluator.evaluate_all(&base.spec, &batch, base.flops) {
                    match r {
                        Ok(e) => evs.push(e),
                        Err(err) => stats.count_failure(&err),
                    }
                }
                (evs, None, stats)
            }
            Strategy::Greedy => {
                // the full grid (baseline included) so the climb can
                // route through unpumped intermediates; re-evaluations
                // are cache hits
                greedy_climb(
                    evaluator,
                    base,
                    &full_grid,
                    &cfg.objective,
                    &reference,
                    remaining_budget,
                )
            }
            Strategy::Anneal => anneal_walk(
                evaluator,
                base,
                &full_grid,
                &cfg.objective,
                &reference,
                remaining_budget,
                cfg.seed.wrapping_add(i as u64),
            ),
            Strategy::Halving => halving_rounds(
                evaluator,
                base,
                &full_grid,
                &cfg.objective,
                &reference,
                remaining_budget,
                cfg.seed.wrapping_add(i as u64),
            ),
        };
        // per-round cache health: hits vs new compiles this strategy
        // round, the resulting hit rate, and what is left of the budget
        if let Some(r) = evaluator.probe() {
            let hits = (evaluator.cache_hits() - hits_before) as u64;
            let new = (evaluator.cache_misses() - misses_before) as u64;
            r.add("dse.cache.hits", hits);
            r.add("dse.cache.new_compiles", new);
            r.gauge(
                &format!("dse.base{i}.hit_rate"),
                hits as f64 / (hits + new).max(1) as f64,
            );
            if let Some(b) = cfg.budget {
                let spent = evaluator.cache_misses() - misses_start;
                r.gauge(
                    &format!("dse.base{i}.budget_remaining"),
                    b.saturating_sub(spent) as f64,
                );
            }
        }
        if let Some(s) = round_sp.as_mut() {
            s.note("issued", stats.issued);
            s.note("truncated", stats.truncated);
        }
        drop(round_sp);
        for e in &mut evs {
            e.base = i;
        }
        evaluated += stats.issued;
        illegal += stats.illegal;
        compile_failed += stats.compile_failed;
        checker_rejected += stats.checker_rejected;
        panicked += stats.panicked;
        timed_out += stats.timed_out;
        truncated |= stats.truncated;
        evaluations.extend(evs);
        if let Some(mut w) = winner {
            w.base = i;
            winners.push(w);
        }
    }

    let front = frontier(&evaluations);
    // never pick something the reference dominates outright
    let beats_reference = |c: &Evaluation| {
        cfg.objective
            .rank(c, &reference)
            .le(&cfg.objective.rank(&reference, &reference))
    };
    // the stochastic strategies may endorse a specific winner (e.g.
    // halving's robust multi-seed choice); a dominated endorsement
    // falls back to rank-selection over everything evaluated, not
    // straight to the reference
    let endorsed = winners
        .into_iter()
        .filter(|w| cfg.objective.feasible(w, &reference))
        .min_by(|a, b| {
            let (ra, rb) = (cfg.objective.rank(a, &reference), cfg.objective.rank(b, &reference));
            ra.0.cmp(&rb.0)
                .then(ra.1.partial_cmp(&rb.1).unwrap_or(std::cmp::Ordering::Equal))
                .then(a.label.cmp(&b.label))
        });
    let chosen = endorsed
        .filter(|c| beats_reference(c))
        .or_else(|| {
            cfg.objective
                .select(&evaluations, &reference)
                .cloned()
                .filter(|c| beats_reference(c))
        })
        .or_else(|| Some(reference.clone()));

    Ok(SearchOutcome {
        frontier: front,
        reference: Some(reference),
        chosen,
        evaluations,
        evaluated,
        illegal,
        compile_failed,
        checker_rejected,
        panicked,
        timed_out,
        truncated,
    })
}

/// Coordinate-descent hill climb from the original point.
fn greedy_climb(
    evaluator: &Evaluator,
    base: &SearchBase,
    grid: &[DesignPoint],
    objective: &Objective,
    reference: &Evaluation,
    budget: Option<usize>,
) -> (Vec<Evaluation>, Option<Evaluation>, WalkStats) {
    let mut evaluations: Vec<Evaluation> = Vec::new();
    let mut stats = WalkStats::default();
    let mut visited: Vec<bool> = vec![false; grid.len()];
    // budget meters new compiles only — cached neighbours are free
    let mut new_compiles = 0usize;

    let mut current = DesignPoint::original();
    let mut current_eval: Option<Evaluation> =
        evaluator.evaluate(&base.spec, &current, base.flops).ok();
    loop {
        let neighbour_idx: Vec<usize> = grid
            .iter()
            .enumerate()
            .filter(|&(i, p)| !visited[i] && differing_dims(p, &current) == 1)
            .map(|(i, _)| i)
            .collect();
        if neighbour_idx.is_empty() {
            break;
        }
        let mut batch: Vec<DesignPoint> = Vec::new();
        for &i in &neighbour_idx {
            let cached = evaluator.contains(&base.spec, &grid[i], base.flops);
            if !cached {
                if let Some(b) = budget {
                    if new_compiles >= b {
                        stats.truncated = true;
                        break;
                    }
                }
                new_compiles += 1;
            }
            visited[i] = true;
            batch.push(grid[i].clone());
            stats.issued += 1;
        }
        if batch.is_empty() {
            break;
        }
        let mut best_step: Option<Evaluation> = None;
        for r in evaluator.evaluate_all(&base.spec, &batch, base.flops) {
            match r {
                Ok(e) => {
                    let better = best_step
                        .as_ref()
                        .map(|b| objective.rank(&e, reference) < objective.rank(b, reference))
                        .unwrap_or(true);
                    if better {
                        best_step = Some(e.clone());
                    }
                    evaluations.push(e);
                }
                Err(err) => stats.count_failure(&err),
            }
        }
        let step = match best_step {
            Some(s) => s,
            None => break,
        };
        let improves = current_eval
            .as_ref()
            .map(|c| objective.rank(&step, reference) < objective.rank(c, reference))
            .unwrap_or(true);
        if !improves || stats.truncated {
            break;
        }
        current = step.point.clone();
        current_eval = Some(step);
    }
    (evaluations, None, stats)
}

/// Simulated annealing over the grid. Deterministic for a fixed seed:
/// proposals come from a seeded [`Rng`], the schedule is geometric, and
/// evaluations are pure, so the whole walk replays identically.
fn anneal_walk(
    evaluator: &Evaluator,
    base: &SearchBase,
    grid: &[DesignPoint],
    objective: &Objective,
    reference: &Evaluation,
    budget: Option<usize>,
    seed: u64,
) -> (Vec<Evaluation>, Option<Evaluation>, WalkStats) {
    let mut stats = WalkStats::default();
    if grid.is_empty() {
        return (Vec::new(), None, stats);
    }
    let mut rng = Rng::new(seed ^ 0xa95ea1);
    let iters = (grid.len() * 2).max(8);
    // budget meters new compiles only; the walk stops early (and is
    // recorded truncated) when a proposal would exceed it
    let mut new_compiles = 0usize;

    let mut evaluations: Vec<Evaluation> = Vec::new();
    let mut visited: Vec<bool> = vec![false; grid.len()];

    // Start at the original (already priced in the baseline sweep).
    // If the original fails to evaluate — or prices to a non-finite
    // energy — seed the walk from the known-legal reference point
    // instead: a walk anchored at an undefined energy used to compute
    // ∞ − ∞ = NaN acceptance terms, making fail→fail proposals
    // undefined behaviour. `current_energy == None` now means "not
    // anchored yet": the first successfully priced proposal is
    // accepted unconditionally, and failed proposals are explicit
    // rejects.
    let mut current = DesignPoint::original();
    let mut current_energy: Option<f64> = evaluator
        .evaluate(&base.spec, &current, base.flops)
        .ok()
        .and_then(|e| energy(objective, &e, reference));
    if current_energy.is_none() {
        // Re-anchor only at a point of *this base's* grid (the global
        // reference may come from another base of a multi-base sweep,
        // which would leave every neighbour set empty), and meter the
        // evaluation like any other proposal — the budget caps new
        // compiles, re-anchoring included.
        if let Some(idx) = grid.iter().position(|p| *p == reference.point) {
            let cached = evaluator.contains(&base.spec, &grid[idx], base.flops);
            let affordable = cached || budget.map(|b| new_compiles < b).unwrap_or(true);
            if affordable {
                if !cached {
                    new_compiles += 1;
                }
                current = grid[idx].clone();
                current_energy = evaluator
                    .evaluate(&base.spec, &current, base.flops)
                    .ok()
                    .and_then(|e| energy(objective, &e, reference));
            }
        }
    }

    let t0 = 0.5f64;
    let t_end = 1e-3f64;
    for step in 0..iters {
        let frac = step as f64 / iters.max(1) as f64;
        let t = t0 * (t_end / t0).powf(frac);

        // Propose: a 1-dimension neighbour, or (15 %) a random jump.
        // Unvisited points are preferred in both branches — the walk is
        // coverage-biased, so a full-length run on a grid that fits the
        // iteration count provably prices every candidate (and the best
        // tracker then equals the exhaustive optimum).
        let neighbours: Vec<usize> = grid
            .iter()
            .enumerate()
            .filter(|(i, p)| !visited[*i] && differing_dims(p, &current) == 1)
            .map(|(i, _)| i)
            .collect();
        let jump = neighbours.is_empty() || rng.f64() < 0.15;
        let cand_idx = if !jump {
            neighbours[rng.range(0, neighbours.len())]
        } else {
            let unvisited: Vec<usize> =
                (0..grid.len()).filter(|&i| !visited[i]).collect();
            if unvisited.is_empty() {
                // fully covered: keep refining among visited neighbours
                let revisitable: Vec<usize> = grid
                    .iter()
                    .enumerate()
                    .filter(|(_, p)| differing_dims(p, &current) == 1)
                    .map(|(i, _)| i)
                    .collect();
                if revisitable.is_empty() {
                    rng.range(0, grid.len())
                } else {
                    revisitable[rng.range(0, revisitable.len())]
                }
            } else {
                unvisited[rng.range(0, unvisited.len())]
            }
        };
        // budget: an uncached proposal is a new compile
        if !evaluator.contains(&base.spec, &grid[cand_idx], base.flops) {
            if let Some(b) = budget {
                if new_compiles >= b {
                    stats.truncated = true;
                    break;
                }
            }
            new_compiles += 1;
        }
        let first_visit = !visited[cand_idx];
        visited[cand_idx] = true;

        stats.issued += 1;
        match evaluator.evaluate(&base.spec, &grid[cand_idx], base.flops) {
            Ok(e) => {
                let cand_energy = energy(objective, &e, reference);
                if first_visit {
                    evaluations.push(e.clone());
                }
                match (cand_energy, current_energy) {
                    // a non-finite candidate is an explicit reject
                    (None, _) => {}
                    // unanchored walk: first priced point is accepted
                    (Some(ce), None) => {
                        current = grid[cand_idx].clone();
                        current_energy = Some(ce);
                    }
                    (Some(ce), Some(cur)) => {
                        let d = ce - cur;
                        if d <= 0.0 || rng.f64() < (-d / t).exp() {
                            current = grid[cand_idx].clone();
                            current_energy = Some(ce);
                        }
                    }
                }
            }
            // a failed proposal is an explicit reject: the walk stays
            // where it is (fail→fail no longer computes ∞ − ∞)
            Err(err) => stats.count_failure(&err),
        }
    }
    // No endorsed winner: everything the walk priced is in
    // `evaluations`, and `run_search`'s rank-selection additionally
    // sees the baseline sweep — a subset endorsement could only tie or
    // lose against it. (Halving *does* endorse, because its multi-seed
    // mean deliberately overrides the single-seed rank.)
    (evaluations, None, stats)
}

/// Successive halving. Fidelity = number of P&R jitter seeds averaged:
/// every survivor of round *r* has been priced under `r + 1` seeds and
/// is ranked by its mean energy, so the final winner is robust to
/// timing jitter rather than lucky under one draw. The budget is spent
/// half on the opening full-grid round, half on the refinement rounds.
fn halving_rounds(
    evaluator: &Evaluator,
    base: &SearchBase,
    grid: &[DesignPoint],
    objective: &Objective,
    reference: &Evaluation,
    budget: Option<usize>,
    seed: u64,
) -> (Vec<Evaluation>, Option<Evaluation>, WalkStats) {
    let mut stats = WalkStats::default();
    if grid.is_empty() {
        return (Vec::new(), None, stats);
    }
    // deterministic sampling order, so a budget-truncated opening round
    // is an unbiased sample rather than a prefix artifact
    let mut order: Vec<usize> = (0..grid.len()).collect();
    Rng::new(seed ^ 0x4a1f).shuffle(&mut order);

    let mut survivors: Vec<usize> = order;
    let mut evaluations: Vec<Evaluation> = Vec::new();
    // candidate index → (energy sum, samples, base-seed evaluation)
    let mut scores: HashMap<usize, (f64, u32, Option<Evaluation>)> = HashMap::new();
    // budget meters new compiles only; round 0 (the opening sample)
    // spends at most half of it, the refinement rounds the rest —
    // cached candidates ride along for free
    let mut remaining = budget;

    let max_rounds = 4usize;
    for round in 0..max_rounds {
        if survivors.is_empty() {
            break;
        }
        // round 0 prices under the base seed (sharing cache entries
        // with every other strategy); later rounds add jitter seeds
        let spec_r = if round == 0 {
            base.spec.clone()
        } else {
            let s = base.spec.seed.wrapping_add(round as u64);
            base.spec.clone().seeded(s)
        };
        if let Some(rem) = remaining.as_mut() {
            // half the budget for the opening sample, but never more
            // than what is actually left (a zero budget stays zero)
            let cap = if round == 0 { (*rem / 2).max(1).min(*rem) } else { *rem };
            let mut uncached = 0usize;
            let mut kept = Vec::with_capacity(survivors.len());
            for &idx in &survivors {
                if evaluator.contains(&spec_r, &grid[idx], base.flops) {
                    kept.push(idx);
                    continue;
                }
                if uncached < cap {
                    uncached += 1;
                    kept.push(idx);
                } else {
                    stats.truncated = true;
                }
            }
            *rem = rem.saturating_sub(uncached);
            survivors = kept;
            if survivors.is_empty() {
                stats.truncated = true;
                break;
            }
        }
        let points: Vec<DesignPoint> = survivors.iter().map(|&i| grid[i].clone()).collect();
        stats.issued += points.len();
        let results = evaluator.evaluate_all(&spec_r, &points, base.flops);
        let mut alive: Vec<usize> = Vec::new();
        for (&idx, r) in survivors.iter().zip(&results) {
            match r {
                Ok(e) => {
                    if round == 0 {
                        evaluations.push(e.clone());
                    }
                    // a non-finite energy cannot be ranked: the
                    // candidate drops out of the tournament (but its
                    // evaluation is still reported above)
                    let en = match energy(objective, e, reference) {
                        Some(en) => en,
                        None => continue,
                    };
                    let slot = scores.entry(idx).or_insert((0.0, 0, None));
                    slot.0 += en;
                    slot.1 += 1;
                    if round == 0 {
                        slot.2 = Some(e.clone());
                    }
                    alive.push(idx);
                }
                Err(err) => stats.count_failure(err),
            }
        }
        // rank by mean energy, keep the better half
        alive.sort_by(|a, b| {
            let ma = scores[a].0 / scores[a].1 as f64;
            let mb = scores[b].0 / scores[b].1 as f64;
            ma.partial_cmp(&mb)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(b))
        });
        if alive.len() <= 2 {
            survivors = alive;
            break;
        }
        alive.truncate((alive.len() + 1) / 2);
        survivors = alive;
    }

    // winner: the surviving candidate with the best mean energy,
    // reported through its base-seed evaluation
    let winner = survivors
        .iter()
        .filter_map(|i| {
            let (sum, n, ev) = scores.get(i)?;
            ev.clone().map(|e| (sum / *n as f64, e))
        })
        .min_by(|(a, _), (b, _)| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(_, e)| e);
    (evaluations, winner, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::coordinator::BuildSpec;
    use crate::ir::PumpMode;

    fn vecadd_bases() -> Vec<SearchBase> {
        let n = 1i64 << 14;
        vec![SearchBase {
            spec: BuildSpec::new(apps::vecadd::build()).bind("N", n).seeded(3),
            flops: apps::vecadd::flops(n),
        }]
    }

    fn small_opts() -> SpaceOptions {
        SpaceOptions {
            vector_widths: vec![2, 4, 8],
            pump_factors: vec![2, 4],
            pump_modes: vec![PumpMode::Resource],
            max_replicas: 1,
            cl0_requests_mhz: vec![],
            mixed_factors: false,
        }
    }

    #[test]
    fn mode_flip_is_one_search_dimension() {
        use crate::ir::RegionPump;
        let base = DesignPoint::original();
        let mk = |fs: Vec<Option<RegionPump>>| DesignPoint {
            regions: Some(fs),
            ..base.clone()
        };
        let r2 = Some(RegionPump::resource(2));
        let t2 = Some(RegionPump::new(2, PumpMode::Throughput));
        let r4 = Some(RegionPump::resource(4));
        // same factor, one region's mode flipped: distance 1
        let a = mk(vec![r2, r2]);
        let b = mk(vec![t2, r2]);
        assert_eq!(differing_dims(&a, &b), 1);
        // mode flip on one region + factor change on the other: 2
        let c = mk(vec![t2, r4]);
        assert_eq!(differing_dims(&a, &c), 2);
        // identical assignments: 0
        assert_eq!(differing_dims(&a, &mk(vec![r2, r2])), 0);
        // uniform mode flip at equal factor is also one pump-axis step
        let u_t = DesignPoint { pump: Some((2, PumpMode::Throughput)), ..base.clone() };
        let u_b = DesignPoint { pump: Some((2, PumpMode::BareFast)), ..base.clone() };
        assert_eq!(differing_dims(&u_t, &u_b), 1);
    }

    #[test]
    fn exhaustive_finds_pumped_optimum_for_vecadd() {
        let device = Device::u280();
        let ev = Evaluator::new();
        let out = run_search(
            &ev,
            &vecadd_bases(),
            &device,
            &small_opts(),
            &SearchConfig::exhaustive(Objective::resource()),
        )
        .unwrap();
        assert!(!out.frontier.is_empty());
        let chosen = out.chosen.as_ref().unwrap();
        assert_eq!(chosen.point.pump, Some((2, PumpMode::Resource)));
        assert_eq!(chosen.point.vectorize, Some(("vadd".into(), 8)));
        assert!(!out.truncated);
    }

    #[test]
    fn budget_cuts_off_early_and_is_recorded() {
        let device = Device::u280();
        let ev = Evaluator::new();
        let cfg = SearchConfig {
            strategy: Strategy::Exhaustive,
            objective: Objective::resource(),
            budget: Some(4),
            seed: 1,
            deadline_ms: None,
            sim_cycle_budget: None,
        };
        let out =
            run_search(&ev, &vecadd_bases(), &device, &small_opts(), &cfg).unwrap();
        assert!(out.evaluated <= 4);
        assert!(out.truncated);
    }

    #[test]
    fn greedy_reaches_the_exhaustive_choice_on_vecadd() {
        let device = Device::u280();
        let opts = small_opts();
        let ex = run_search(
            &Evaluator::new(),
            &vecadd_bases(),
            &device,
            &opts,
            &SearchConfig::exhaustive(Objective::resource()),
        )
        .unwrap();
        let gr = run_search(
            &Evaluator::new(),
            &vecadd_bases(),
            &device,
            &opts,
            &SearchConfig::greedy(Objective::resource()),
        )
        .unwrap();
        let (ec, gc) = (ex.chosen.unwrap(), gr.chosen.unwrap());
        assert_eq!(ec.point, gc.point, "greedy diverged: {} vs {}", ec.label, gc.label);
    }

    #[test]
    fn anneal_reaches_the_exhaustive_choice_on_vecadd() {
        // the vecadd space is small: a full-length annealing walk must
        // find the same optimum the exhaustive sweep proves is best
        let device = Device::u280();
        let opts = small_opts();
        let ex = run_search(
            &Evaluator::new(),
            &vecadd_bases(),
            &device,
            &opts,
            &SearchConfig::exhaustive(Objective::resource()),
        )
        .unwrap();
        let an = run_search(
            &Evaluator::new(),
            &vecadd_bases(),
            &device,
            &opts,
            &SearchConfig::anneal(Objective::resource()).with_seed(42),
        )
        .unwrap();
        let (ec, ac) = (ex.chosen.unwrap(), an.chosen.unwrap());
        assert_eq!(ec.point, ac.point, "anneal diverged: {} vs {}", ec.label, ac.label);
    }

    #[test]
    fn anneal_is_deterministic_for_a_seed() {
        let device = Device::u280();
        let opts = small_opts();
        let run = |seed: u64| {
            let out = run_search(
                &Evaluator::new(),
                &vecadd_bases(),
                &device,
                &opts,
                &SearchConfig::anneal(Objective::resource()).with_seed(seed),
            )
            .unwrap();
            (
                out.chosen.unwrap().point,
                out.evaluated,
                out.evaluations.iter().map(|e| e.label.clone()).collect::<Vec<_>>(),
            )
        };
        let (p1, n1, l1) = run(7);
        let (p2, n2, l2) = run(7);
        assert_eq!(p1, p2, "same seed must choose the same point");
        assert_eq!(n1, n2, "same seed must issue the same evaluation count");
        assert_eq!(l1, l2, "same seed must walk the same path");
    }

    #[test]
    fn anneal_respects_budget() {
        // budget meters new compiles: the walk may issue more
        // evaluations than the budget (cache hits are free) but must
        // not compile more than baseline + budget candidates
        let device = Device::u280();
        let cfg = SearchConfig {
            strategy: Strategy::Anneal,
            objective: Objective::resource(),
            budget: Some(3),
            seed: 5,
            deadline_ms: None,
            sim_cycle_budget: None,
        };
        let ev = Evaluator::new();
        let out = run_search(&ev, &vecadd_bases(), &device, &small_opts(), &cfg).unwrap();
        // baseline (4 unpumped candidates) + at most 3 walk compiles
        assert!(ev.cache_misses() <= 4 + 3, "compiled {} candidates", ev.cache_misses());
        // a budgeted anneal still returns something sane
        let chosen = out.chosen.unwrap();
        let reference = out.reference.unwrap();
        assert!(chosen.resource_score <= reference.resource_score + 1e-12);
    }

    #[test]
    fn budget_meters_new_compiles_so_warm_cache_explores_more() {
        // regression: cache hits used to count against the budget, so
        // a warm cache could exhaust it while compiling nothing. Now a
        // warm run under the same budget explores at least as many
        // points as the cold one — strictly more here, because the
        // cold run's budget was spent entirely on the baseline.
        let device = Device::u280();
        let cfg = SearchConfig {
            strategy: Strategy::Exhaustive,
            objective: Objective::resource(),
            budget: Some(4),
            seed: 1,
            deadline_ms: None,
            sim_cycle_budget: None,
        };
        let ev = Evaluator::new();
        let cold = run_search(&ev, &vecadd_bases(), &device, &small_opts(), &cfg).unwrap();
        assert!(cold.truncated, "tight budget must truncate the cold sweep");
        let warm = run_search(&ev, &vecadd_bases(), &device, &small_opts(), &cfg).unwrap();
        assert!(
            warm.evaluations.len() > cold.evaluations.len(),
            "warm run explored {} ≤ cold {}",
            warm.evaluations.len(),
            cold.evaluations.len()
        );
        // and a run over a fully warmed cache is never truncated
        let full = run_search(&ev, &vecadd_bases(), &device, &small_opts(), &cfg).unwrap();
        let again = run_search(&ev, &vecadd_bases(), &device, &small_opts(), &cfg).unwrap();
        assert!(again.evaluations.len() >= full.evaluations.len());
    }

    #[test]
    fn halving_reaches_the_exhaustive_choice_on_vecadd() {
        let device = Device::u280();
        let opts = small_opts();
        let ex = run_search(
            &Evaluator::new(),
            &vecadd_bases(),
            &device,
            &opts,
            &SearchConfig::exhaustive(Objective::resource()),
        )
        .unwrap();
        let ha = run_search(
            &Evaluator::new(),
            &vecadd_bases(),
            &device,
            &opts,
            &SearchConfig::halving(Objective::resource()).with_seed(11),
        )
        .unwrap();
        let (ec, hc) = (ex.chosen.unwrap(), ha.chosen.unwrap());
        assert_eq!(ec.point, hc.point, "halving diverged: {} vs {}", ec.label, hc.label);
    }

    #[test]
    fn halving_budget_samples_instead_of_full_grid() {
        let device = Device::u280();
        let cfg = SearchConfig {
            strategy: Strategy::Halving,
            objective: Objective::resource(),
            budget: Some(8),
            seed: 2,
            deadline_ms: None,
            sim_cycle_budget: None,
        };
        let out =
            run_search(&Evaluator::new(), &vecadd_bases(), &device, &small_opts(), &cfg)
                .unwrap();
        assert!(out.truncated, "a tight budget must be recorded as truncation");
        assert!(out.chosen.is_some());
    }

    #[test]
    fn repeated_search_is_fully_cached() {
        let device = Device::u280();
        let ev = Evaluator::new();
        let cfg = SearchConfig::exhaustive(Objective::resource());
        run_search(&ev, &vecadd_bases(), &device, &small_opts(), &cfg).unwrap();
        let misses_after_first = ev.cache_misses();
        run_search(&ev, &vecadd_bases(), &device, &small_opts(), &cfg).unwrap();
        assert_eq!(
            ev.cache_misses(),
            misses_after_first,
            "second sweep must be served from the cache"
        );
        assert!(ev.cache_hits() > 0);
    }

    #[test]
    fn strategy_names_round_trip() {
        for s in [Strategy::Exhaustive, Strategy::Greedy, Strategy::Anneal, Strategy::Halving] {
            assert_eq!(Strategy::from_name(s.name()), Some(s));
        }
        assert_eq!(Strategy::from_name("nonsense"), None);
    }
}
