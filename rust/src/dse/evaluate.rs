//! Candidate evaluation: compile + price + rate-model, in parallel,
//! behind a content-hashed memoization cache.
//!
//! Every candidate runs through the real pipeline
//! ([`crate::coordinator::pipeline::compile`]) — the same path the
//! experiment tables use — then derives the two Pareto axes: a
//! DSP-weighted resource score from the [`DesignReport`] and a modeled
//! throughput from the analytic rate model at the achieved effective
//! clock. Evaluations are fanned out over OS threads with
//! `std::thread::scope` (no external dependencies), and keyed by a
//! fingerprint of the *content* of the work (printed SDFG, bindings,
//! candidate, seed), so repeated sweeps — a greedy refinement after an
//! exhaustive pass, a re-run with a wider grid — are incremental.
//!
//! The cache has two tiers: the in-process `HashMap` and, when the
//! evaluator is created with [`Evaluator::with_cache_dir`], the on-disk
//! store of [`super::cache`], so repeated *CLI invocations* are
//! incremental too ([`Evaluator::flush`] persists new entries).

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::codegen::DesignReport;
use crate::coordinator::pipeline::{
    compile_from_prefix_observed, compile_staged, stage_prefix_observed, BuildSpec, Compiled,
    Stage, StagedError, StagedPrefix,
};
use crate::hw::ResourceVec;
use crate::ir::{PumpMode, RegionPump};
use crate::sim::{rate_model, Arena, ArenaStats};
use crate::util::{fnv1a, lock_unpoisoned, FNV_OFFSET};

use super::cache;
use super::faults::{self, FaultPlan};
use super::pareto::resource_score;
use super::space::DesignPoint;

/// Why a cached candidate failed: rejected by a legality check
/// (transform precondition, indivisible binding), by a genuine
/// compile error in lowering, by the static design-rule checker
/// (`analysis::checker`) after a successful compile, or by the
/// supervision layer — a candidate that panicked mid-evaluation
/// ([`FailKind::Panic`]) or blew its wall-clock/slow-cycle budget
/// ([`FailKind::Timeout`]). Reports and `--verify` keep them apart — a
/// legality rejection is expected pruning, a compile error is a bug
/// surface, a checker rejection is a design that would deadlock, and
/// the two supervision kinds are *quarantined*: cached like other
/// failures so they are never retried within a run, but filtered out
/// of the persistent store so a later run (possibly with a bigger
/// budget, or a fixed tasklet) retries them fresh.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailKind {
    Legality,
    Compile,
    Check,
    /// The evaluation panicked and was caught by the supervisor.
    Panic,
    /// The evaluation exceeded its wall-clock or slow-cycle budget.
    Timeout,
}

impl FailKind {
    pub fn name(&self) -> &'static str {
        match self {
            FailKind::Legality => "legality",
            FailKind::Compile => "compile",
            FailKind::Check => "check",
            FailKind::Panic => "panic",
            FailKind::Timeout => "timeout",
        }
    }

    /// Supervision failures are quarantined in memory for the rest of
    /// the run but never persisted: a panic or timeout says something
    /// about *this* process (its budget, its bugs), not about the
    /// candidate's content, so the next run gets to retry it.
    pub fn quarantined(&self) -> bool {
        matches!(self, FailKind::Panic | FailKind::Timeout)
    }
}

/// A per-candidate failure, cached alongside successes so infeasible
/// points are never re-compiled.
#[derive(Clone, Debug, PartialEq)]
pub struct EvalError {
    pub kind: FailKind,
    pub message: String,
}

impl EvalError {
    pub fn legality(message: impl Into<String>) -> EvalError {
        EvalError { kind: FailKind::Legality, message: message.into() }
    }

    pub fn compile(message: impl Into<String>) -> EvalError {
        EvalError { kind: FailKind::Compile, message: message.into() }
    }

    pub fn check(message: impl Into<String>) -> EvalError {
        EvalError { kind: FailKind::Check, message: message.into() }
    }

    pub fn panicked(message: impl Into<String>) -> EvalError {
        EvalError { kind: FailKind::Panic, message: message.into() }
    }

    pub fn timeout(message: impl Into<String>) -> EvalError {
        EvalError { kind: FailKind::Timeout, message: message.into() }
    }
}

impl std::fmt::Display for EvalError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] {}", self.kind.name(), self.message)
    }
}

/// An evaluated candidate: the priced design plus the derived metrics
/// the Pareto analysis and the search rank on.
#[derive(Clone, Debug)]
pub struct Evaluation {
    pub point: DesignPoint,
    /// `<design name> <point label>`, e.g. `gemm_p32 R2`.
    pub label: String,
    /// Index of the [`super::search::SearchBase`] this evaluation came
    /// from — stamped by `run_search` (0 for direct evaluations), used
    /// by `--verify` to rebuild the point at golden scale.
    pub base: usize,
    pub report: DesignReport,
    /// Rate-model cycle count of one workload execution (slow domain).
    pub slow_cycles: u64,
    /// Modeled wall-clock seconds at the achieved effective clock.
    pub time_s: f64,
    /// Modeled throughput in GOp/s across all replicas.
    pub gops: f64,
    /// Resources summed over SLR replicas.
    pub total_resources: ResourceVec,
    /// Scalar resource axis (lower is better), × replicas.
    pub resource_score: f64,
    /// Does one replica fit its SLR pool?
    pub fits: bool,
}

fn pump_tag(p: &Option<(usize, PumpMode)>) -> String {
    match p {
        None => "-".into(),
        Some((f, m)) => format!("{}{f}", m.letter()),
    }
}

/// Tag of a mixed per-region assignment, e.g. `m:2r,4t,-` (`-` =
/// none; every entry carries its factor plus its mode letter). Shared
/// with the cache codec (`pr=` field) so the on-disk encoding and the
/// fingerprint tag cannot diverge.
pub(crate) fn regions_tag(r: &Option<Vec<Option<RegionPump>>>) -> String {
    match r {
        None => "-".into(),
        Some(fs) => {
            let body = fs
                .iter()
                .map(|p| {
                    p.map(|p| format!("{}{}", p.factor, p.mode.letter()))
                        .unwrap_or_else(|| "-".into())
                })
                .collect::<Vec<_>>()
                .join(",");
            format!("m:{body}")
        }
    }
}

/// Content fingerprint of one (spec, candidate, workload) evaluation.
/// Chains from the base's cached print hash ([`BuildSpec::sdfg_fnv`]),
/// so two sweeps over structurally identical graphs share cache
/// entries regardless of how they were built — without re-printing the
/// whole SDFG per candidate, which used to dominate warm-cache sweeps.
/// (Key derivation has changed over time — prefix-hash chaining in
/// schema v3, mode-carrying pump/region tags in schema v4 — and each
/// change bumps the on-disk cache schema, so older stores cold-start.)
pub fn fingerprint(base: &BuildSpec, point: &DesignPoint, flops: f64) -> u64 {
    let mut h = fnv1a(FNV_OFFSET, &base.sdfg_fnv().to_le_bytes());
    for (s, v) in &base.bindings {
        h = fnv1a(h, s.as_bytes());
        h = fnv1a(h, &v.to_le_bytes());
    }
    h = fnv1a(h, &base.seed.to_le_bytes());
    h = fnv1a(h, &[base.stream as u8]);
    if let Some(mhz) = base.cl0_request_mhz {
        h = fnv1a(h, &mhz.to_bits().to_le_bytes());
    }
    if let Some((map, w)) = &base.vectorize {
        h = fnv1a(h, map.as_bytes());
        h = fnv1a(h, &(*w as u64).to_le_bytes());
    }
    h = fnv1a(h, pump_tag(&base.pump).as_bytes());
    h = fnv1a(h, regions_tag(&base.pump_regions).as_bytes());
    h = fnv1a(h, &(base.slr_replicas as u64).to_le_bytes());
    // the candidate
    if let Some((map, w)) = &point.vectorize {
        h = fnv1a(h, map.as_bytes());
        h = fnv1a(h, &(*w as u64).to_le_bytes());
    }
    h = fnv1a(h, pump_tag(&point.pump).as_bytes());
    h = fnv1a(h, regions_tag(&point.regions).as_bytes());
    h = fnv1a(h, &(point.replicas as u64).to_le_bytes());
    if let Some(mhz) = point.cl0_request_mhz {
        h = fnv1a(h, &mhz.to_bits().to_le_bytes());
    }
    fnv1a(h, &flops.to_bits().to_le_bytes())
}

/// Derive the Pareto metrics from a compiled candidate (shared by the
/// direct and the prefix-cached compile paths, so they cannot diverge).
fn finish_evaluation(c: Compiled, point: &DesignPoint, flops: f64) -> Evaluation {
    let stats = rate_model(&c.design);
    let time_s = stats.seconds_at(c.report.effective_mhz);
    let replicas = point.replicas.max(1) as f64;
    let gops = flops * replicas / time_s / 1e9;
    Evaluation {
        label: format!("{} {}", c.design.name, point.label()),
        point: point.clone(),
        base: 0,
        slow_cycles: stats.slow_cycles,
        time_s,
        gops,
        total_resources: c.report.resources.scaled(replicas),
        resource_score: resource_score(&c.report.util) * replicas,
        fits: c.report.util.max_fraction() <= 1.0,
        report: c.report,
    }
}

fn classify(e: StagedError) -> EvalError {
    match e.stage {
        Stage::Transform | Stage::Bind => EvalError::legality(e.message),
        Stage::Lower => EvalError::compile(e.message),
    }
}

/// Best-effort text of a caught panic payload (`panic!` carries a
/// `&str` or a `String`; anything else is opaque).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Pre-simulation gate: run the static design-rule checker over the
/// compiled candidate and reject it before it ever reaches the rate
/// model or the exact simulator. The checker is ~free next to a
/// compile, and a rejected design is one that would deadlock or wedge
/// — pricing it would poison the Pareto front.
fn design_rule_gate(c: &Compiled) -> Result<(), EvalError> {
    let report = crate::analysis::checker::check(&c.sdfg, &c.design);
    match report.first_error() {
        None => Ok(()),
        Some(first) => Err(EvalError::check(format!(
            "{first} (+{} more error(s))",
            report.errors() - 1
        ))),
    }
}

/// Compile and price one candidate; `flops` is the workload size the
/// throughput axis is derived from.
pub fn evaluate_point(
    base: &BuildSpec,
    point: &DesignPoint,
    flops: f64,
) -> Result<Evaluation, EvalError> {
    let spec = point.apply_to(base);
    let c = compile_staged(spec).map_err(classify)?;
    design_rule_gate(&c)?;
    Ok(finish_evaluation(c, point, flops))
}

/// Key of one shared transform prefix: (base graph content hash,
/// vectorize choice, streaming on). Seed, bindings, pump and replicas
/// all apply *after* the prefix, so they stay out of the key — a
/// halving sweep re-pricing under five jitter seeds reuses one prefix.
type PrefixKey = (u64, Option<(String, usize)>, bool);

/// Reservoir of simulation arenas for the evaluation/verification
/// loop: one arena per concurrently simulating worker, checked out
/// around each exact-sim run and checked back in afterwards, so a
/// sweep over thousands of candidates reuses a handful of arenas whose
/// slabs grew once to the workload's high-water mark — the
/// zero-steady-state-allocation loop (DESIGN.md §10). Sequential
/// callers keep hitting the same warmed arena; concurrent callers pop
/// distinct ones (the pool grows to the observed parallelism, never
/// beyond it). The engines perform the high-water-mark reset on entry,
/// so a checked-in arena is always reusable even after an errored run.
#[derive(Default)]
pub struct ArenaPool {
    arenas: Mutex<Vec<Arena>>,
    /// Total checkouts over the pool's lifetime (telemetry).
    checkouts: AtomicUsize,
    /// Arenas checked out right now.
    in_flight: AtomicUsize,
    /// High-water mark of concurrent checkouts — the pool's eventual
    /// resident size, since it grows to the observed parallelism.
    peak_in_flight: AtomicUsize,
}

impl ArenaPool {
    /// Run `f` inside a pooled arena (checkout → run → checkin). The
    /// checkin rides a drop guard, so a panicking `f` — a buggy or
    /// fault-injected candidate under the supervisor's `catch_unwind` —
    /// still returns the arena and decrements the in-flight count
    /// instead of leaking the slot; the engines reset arenas on entry,
    /// so a returned arena is reusable whatever state `f` left it in.
    pub fn run<R>(&self, f: impl FnOnce(&mut Arena) -> R) -> R {
        struct Checkin<'p> {
            pool: &'p ArenaPool,
            arena: Option<Arena>,
        }
        impl Drop for Checkin<'_> {
            fn drop(&mut self) {
                self.pool.in_flight.fetch_sub(1, Ordering::Relaxed);
                if let Some(arena) = self.arena.take() {
                    lock_unpoisoned(&self.pool.arenas).push(arena);
                }
            }
        }
        let arena = lock_unpoisoned(&self.arenas).pop().unwrap_or_default();
        self.checkouts.fetch_add(1, Ordering::Relaxed);
        let now = self.in_flight.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak_in_flight.fetch_max(now, Ordering::Relaxed);
        let mut guard = Checkin { pool: self, arena: Some(arena) };
        f(guard.arena.as_mut().expect("arena checked out"))
    }

    /// Lifetime checkout count.
    pub fn checkouts(&self) -> usize {
        self.checkouts.load(Ordering::Relaxed)
    }

    /// High-water mark of concurrent checkouts.
    pub fn peak_in_flight(&self) -> usize {
        self.peak_in_flight.load(Ordering::Relaxed)
    }

    /// Arenas currently resident in the pool.
    pub fn pooled(&self) -> usize {
        lock_unpoisoned(&self.arenas).len()
    }

    /// Counters summed over every pooled arena (checked-out arenas are
    /// invisible until they return).
    pub fn stats(&self) -> ArenaStats {
        let arenas = lock_unpoisoned(&self.arenas);
        let mut sum = ArenaStats::default();
        for a in arenas.iter() {
            sum.accumulate(&a.stats());
        }
        sum
    }
}

/// The memo table plus the keys this run used, under ONE lock so the
/// warm-cache hot path pays a single acquisition per evaluation.
#[derive(Default)]
struct MemoState {
    entries: HashMap<u64, Result<Evaluation, EvalError>>,
    /// Keys used this run (hits + new compiles):
    /// [`Evaluator::flush_compacted`] persists only these.
    touched: HashSet<u64>,
}

/// Per-candidate budgets the supervision layer enforces. Stored as
/// atomics (0 = unarmed) so [`Evaluator::set_limits`] applies a
/// `SearchConfig`'s budgets through the same `&Evaluator` the worker
/// threads already share — no interior `&mut` plumbing.
#[derive(Default)]
struct EvalLimits {
    /// Wall-clock budget per candidate evaluation, in milliseconds.
    wall_ms: AtomicU64,
    /// Slow-cycle budget for exact-sim spot checks (`--verify`).
    sim_cycles: AtomicU64,
    /// Worker-thread count for batch evaluation and the parallel
    /// verify path (0 = available parallelism; the `--threads` flag).
    threads: AtomicUsize,
}

/// Memoizing, thread-parallel candidate evaluator. Failures are cached
/// too — tagged legality vs compile — so an infeasible candidate is
/// never recompiled on repeated sweeps. With a cache directory the
/// memo table is additionally loaded from / flushed to a versioned
/// on-disk store, making separate processes incremental.
///
/// Candidate compilation is zero-copy with respect to the base graph:
/// specs share the SDFG behind an `Arc`, and the vectorize+stream
/// transform prefix is computed once per distinct choice and shared
/// across every candidate (and worker thread) that agrees on it.
#[derive(Default)]
pub struct Evaluator {
    cache: Mutex<MemoState>,
    /// Shared vectorize+stream prefixes (failures cached too, so a
    /// broken prefix is not recomputed per candidate).
    prefixes: Mutex<HashMap<PrefixKey, Arc<Result<StagedPrefix, StagedError>>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    /// Persistent store path, when created with `with_cache_dir`.
    disk_path: Option<PathBuf>,
    /// Entries loaded from disk at construction.
    loaded: usize,
    /// Why the disk store was ignored, if it was.
    cold_reason: Option<String>,
    /// Per-worker simulation arenas for the exact-sim paths hanging off
    /// this evaluator (`dse --verify`, golden spot checks).
    arenas: ArenaPool,
    /// Optional telemetry recorder (`--trace-out`): per-candidate spans
    /// tagged with fingerprint + outcome, prefix-cache-hit instants,
    /// and compile-stage spans on the miss path. `None` keeps every
    /// instrumentation site a branch on a null handle.
    recorder: Option<Arc<crate::telemetry::Recorder>>,
    /// Per-candidate wall/slow-cycle budgets (supervision layer).
    limits: EvalLimits,
    /// Deterministic fault injection (`--inject-faults`), tests/CI only
    /// in practice — `None` costs one branch per evaluation.
    faults: Option<FaultPlan>,
    /// Evaluation ordinals issued so far: the deterministic index the
    /// fault plan keys on. Batch evaluation reserves a contiguous block
    /// up front, so worker interleaving never reorders ordinals.
    issued: AtomicUsize,
    /// Set when cache-flush retries were exhausted: the evaluator keeps
    /// working in-memory-only and later flushes become warned no-ops.
    degraded: AtomicBool,
}

impl Evaluator {
    pub fn new() -> Evaluator {
        Evaluator::default()
    }

    /// An evaluator whose memo cache is backed by
    /// `<dir>/<cache::FILE_NAME>`. A missing store is a silent cold
    /// start; an unreadable or corrupt one is a cold start with a
    /// reason ([`Evaluator::cold_reason`]) — never an error.
    pub fn with_cache_dir(dir: &Path) -> Evaluator {
        let path = dir.join(cache::FILE_NAME);
        let loaded = cache::load(&path);
        let n = loaded.entries.len();
        Evaluator {
            cache: Mutex::new(MemoState { entries: loaded.entries, touched: HashSet::new() }),
            disk_path: Some(path),
            loaded: n,
            cold_reason: loaded.cold_reason,
            ..Evaluator::default()
        }
    }

    /// Attach a telemetry recorder: every evaluation from here on
    /// emits a `dse.candidate` span (fingerprint + outcome) and the
    /// miss path emits per-stage compile spans.
    pub fn observed(mut self, rec: Arc<crate::telemetry::Recorder>) -> Evaluator {
        self.recorder = Some(rec);
        self
    }

    /// The attached recorder as a nullable handle — the shape every
    /// instrumentation site branches on.
    pub fn probe(&self) -> Option<&crate::telemetry::Recorder> {
        self.recorder.as_deref()
    }

    /// Attach a deterministic fault plan (`--inject-faults`): armed
    /// faults fire at their evaluation ordinals and cache
    /// write-attempt indices. Used by tests and CI to prove the
    /// supervision paths; production evaluators never carry one.
    pub fn with_faults(mut self, plan: FaultPlan) -> Evaluator {
        self.faults = Some(plan);
        self
    }

    /// The attached fault plan, if any (the CLI reports its
    /// armed-vs-fired summary after a sweep).
    pub fn faults(&self) -> Option<&FaultPlan> {
        self.faults.as_ref()
    }

    /// Arm (or clear, with `None`) the per-candidate budgets.
    /// `run_search` calls this with its `SearchConfig`'s limits on
    /// entry; the serve daemon re-arms per request.
    pub fn set_limits(&self, wall_ms: Option<u64>, sim_cycles: Option<u64>) {
        self.limits.wall_ms.store(wall_ms.unwrap_or(0), Ordering::Relaxed);
        self.limits.sim_cycles.store(sim_cycles.unwrap_or(0), Ordering::Relaxed);
    }

    /// Set the worker-thread count for batch evaluation and the
    /// parallel verify path: `0` restores the default (available
    /// parallelism), `1` forces serial execution — the CLI's
    /// `--threads` flag lands here.
    pub fn set_threads(&self, threads: usize) {
        self.limits.threads.store(threads, Ordering::Relaxed);
    }

    /// The resolved worker-thread count (`--threads`, with 0/unset
    /// meaning whatever the machine offers).
    pub fn threads(&self) -> usize {
        crate::sim::resolve_threads(self.limits.threads.load(Ordering::Relaxed))
    }

    /// The armed per-candidate wall-clock budget, if any.
    pub fn wall_budget(&self) -> Option<Duration> {
        match self.limits.wall_ms.load(Ordering::Relaxed) {
            0 => None,
            ms => Some(Duration::from_millis(ms)),
        }
    }

    /// The armed slow-cycle budget for exact-sim spot checks, falling
    /// back to the verify default when unarmed.
    pub fn sim_cycle_budget(&self) -> u64 {
        match self.limits.sim_cycles.load(Ordering::Relaxed) {
            0 => super::verify::MAX_VERIFY_CYCLES,
            n => n,
        }
    }

    /// Has the persistent cache degraded to in-memory-only operation
    /// (flush retries exhausted)? Reported in `BENCH_serve.json`.
    pub fn degraded(&self) -> bool {
        self.degraded.load(Ordering::Relaxed)
    }

    pub fn cache_hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn cache_misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Entries loaded from the persistent store at construction.
    pub fn loaded_entries(&self) -> usize {
        self.loaded
    }

    /// Why the persistent store was discarded at load, if it was
    /// (schema mismatch, corruption).
    pub fn cold_reason(&self) -> Option<&str> {
        self.cold_reason.as_deref()
    }

    /// The evaluator's simulation-arena pool: exact-sim spot checks
    /// (`dse --verify`) run inside it so repeated candidates reuse the
    /// slabs the first one grew.
    pub fn arenas(&self) -> &ArenaPool {
        &self.arenas
    }

    /// Persist the memo cache to the store this evaluator was created
    /// with. Takes the advisory flush lock (`<store>.lock`, bounded
    /// retry; on contention this flush is *skipped* with a warning —
    /// entries stay in memory for the next flush — rather than
    /// blocking or racing a concurrent flusher), re-reads the file
    /// under the lock and merges (in-memory entries win), then writes
    /// atomically (tmp + rename) with bounded-backoff retry on IO
    /// failure. Exhausted retries degrade the evaluator to
    /// in-memory-only operation — warned once, counted in telemetry,
    /// never a crash. Quarantined entries ([`FailKind::quarantined`])
    /// are filtered out: panics and timeouts are never persisted.
    /// Returns the total entries written (`Ok(0)` for a skipped or
    /// degraded flush, and without a cache directory).
    pub fn flush(&self) -> Result<usize, String> {
        let path = match &self.disk_path {
            Some(p) => p.clone(),
            None => return Ok(0),
        };
        if self.degraded.load(Ordering::Relaxed) {
            eprintln!(
                "warning: cache degraded to in-memory-only; not flushing '{}'",
                path.display()
            );
            return Ok(0);
        }
        let _lock = match cache::FlushLock::acquire(&path) {
            Some(l) => l,
            None => {
                eprintln!(
                    "warning: cache store '{}' is locked by a concurrent flusher; \
                     skipping this flush (entries stay in memory)",
                    path.display()
                );
                if let Some(r) = self.probe() {
                    r.add("dse.cache.flush_lock_skips", 1);
                }
                return Ok(0);
            }
        };
        let mut merged: HashMap<u64, Result<Evaluation, EvalError>> = {
            let state = lock_unpoisoned(&self.cache);
            state
                .entries
                .iter()
                .filter(|(_, v)| !matches!(v, Err(e) if e.kind.quarantined()))
                .map(|(k, v)| (*k, v.clone()))
                .collect()
        };
        cache::merge(&mut merged, cache::load(&path).entries);
        match cache::save_retry(&path, &merged, self.faults.as_ref()) {
            Ok(()) => Ok(merged.len()),
            Err(e) => {
                self.degraded.store(true, Ordering::Relaxed);
                eprintln!(
                    "warning: cache flush to '{}' failed ({e}); degrading to \
                     in-memory-only for the rest of this process",
                    path.display()
                );
                if let Some(r) = self.probe() {
                    r.add("dse.cache.degraded", 1);
                }
                Ok(0)
            }
        }
    }

    /// Compacting flush (`--cache-compact`): an *eviction*, not a
    /// merge. The store is rewritten with exactly the entries this run
    /// used — cache hits and new compiles — so records whose
    /// fingerprint schema no longer matches (an old-version store that
    /// cold-started) are shed, and so is every valid entry the run did
    /// not touch: month-scale stores stop growing append-only at the
    /// price of recompiling anything evicted that a later sweep wants
    /// again. Compact from a run that exercises what should survive
    /// (e.g. `--app all`), not a narrow one-app sweep over a shared
    /// store. Returns `(records on disk before, records written)`; a
    /// no-op `(0, 0)` without a cache directory.
    pub fn flush_compacted(&self) -> Result<(usize, usize), String> {
        let path = match &self.disk_path {
            Some(p) => p.clone(),
            None => return Ok((0, 0)),
        };
        if self.degraded.load(Ordering::Relaxed) {
            return Err("cache degraded to in-memory-only; not compacting".into());
        }
        // compaction is an explicit, destructive rewrite: on lock
        // contention fail loudly (the user can rerun) instead of the
        // merging flush's silent skip
        let _lock = cache::FlushLock::acquire(&path).ok_or_else(|| {
            format!(
                "cache store '{}' is locked by a concurrent flusher; not compacting",
                path.display()
            )
        })?;
        let state = lock_unpoisoned(&self.cache);
        let kept: HashMap<u64, Result<Evaluation, EvalError>> = state
            .entries
            .iter()
            .filter(|(k, v)| {
                state.touched.contains(*k) && !matches!(v, Err(e) if e.kind.quarantined())
            })
            .map(|(k, v)| (*k, v.clone()))
            .collect();
        cache::compact(&path, &kept)
    }

    /// Distinct transform prefixes computed so far (one per
    /// (graph, vectorize, stream) choice — *not* one per candidate).
    pub fn prefix_entries(&self) -> usize {
        lock_unpoisoned(&self.prefixes).len()
    }

    /// Is this exact (spec, candidate, workload) content already in the
    /// memo cache? Used by the search budget, which meters *new
    /// compiles* only — cache hits are free.
    pub fn contains(&self, base: &BuildSpec, point: &DesignPoint, flops: f64) -> bool {
        let key = fingerprint(base, point, flops);
        lock_unpoisoned(&self.cache).entries.contains_key(&key)
    }

    /// Evaluate one candidate, hitting the cache when the same content
    /// was evaluated before. One lock acquisition on the hit path.
    /// Reserves the next evaluation ordinal — the deterministic index
    /// fault injection keys on.
    pub fn evaluate(
        &self,
        base: &BuildSpec,
        point: &DesignPoint,
        flops: f64,
    ) -> Result<Evaluation, EvalError> {
        let ordinal = self.issued.fetch_add(1, Ordering::Relaxed);
        self.evaluate_indexed(base, point, flops, ordinal)
    }

    fn evaluate_indexed(
        &self,
        base: &BuildSpec,
        point: &DesignPoint,
        flops: f64,
        ordinal: usize,
    ) -> Result<Evaluation, EvalError> {
        let key = fingerprint(base, point, flops);
        let mut sp = self.probe().map(|r| r.span("dse.candidate"));
        if let Some(s) = sp.as_mut() {
            s.note("fingerprint", format!("{key:016x}"));
        }
        {
            let mut state = lock_unpoisoned(&self.cache);
            if let Some(hit) = state.entries.get(&key) {
                let hit = hit.clone();
                state.touched.insert(key);
                self.hits.fetch_add(1, Ordering::Relaxed);
                if let Some(s) = sp.as_mut() {
                    s.note("outcome", "memo_hit");
                }
                return hit;
            }
        }
        let ev = self.evaluate_supervised(base, point, flops, ordinal);
        self.misses.fetch_add(1, Ordering::Relaxed);
        if let Some(s) = sp.as_mut() {
            s.note(
                "outcome",
                match &ev {
                    Ok(_) => "new_compile",
                    Err(e) => match e.kind {
                        FailKind::Legality => "legality",
                        FailKind::Check => "checker_reject",
                        FailKind::Compile => "compile_fail",
                        FailKind::Panic => "panic",
                        FailKind::Timeout => "timeout",
                    },
                },
            );
        }
        let mut state = lock_unpoisoned(&self.cache);
        state.touched.insert(key);
        state.entries.insert(key, ev.clone());
        ev
    }

    /// The supervised miss path: fire any fault armed for this ordinal,
    /// run the real evaluation under `catch_unwind` so a panicking
    /// candidate becomes a classified [`FailKind::Panic`] instead of an
    /// unwinding sweep, and apply the post-hoc wall-clock check — a
    /// candidate that *completed* past its budget is still quarantined
    /// as [`FailKind::Timeout`] (its latency, not its answer, is what
    /// the budget bounds). A panic takes precedence over the deadline:
    /// it names a bug, the timeout only a budget.
    fn evaluate_supervised(
        &self,
        base: &BuildSpec,
        point: &DesignPoint,
        flops: f64,
        ordinal: usize,
    ) -> Result<Evaluation, EvalError> {
        let wall = self.wall_budget();
        let injected = self.faults.as_ref().and_then(|p| p.at_eval(ordinal));
        let started = Instant::now();
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if let (Some(kind), Some(plan)) = (injected, self.faults.as_ref()) {
                plan.note_fired(kind);
                match kind {
                    faults::FaultKind::Panic => {
                        panic!("injected fault: evaluation #{ordinal} panicked")
                    }
                    faults::FaultKind::Wedge => {
                        let held = faults::wedge_spin(wall);
                        return Err(EvalError::timeout(format!(
                            "evaluation #{ordinal} wedged (injected); reaped after {}ms",
                            held.as_millis()
                        )));
                    }
                    faults::FaultKind::Slow => faults::crawl(wall),
                    faults::FaultKind::CacheFail => {} // fires at write time
                }
            }
            self.evaluate_uncached(base, point, flops)
        }));
        let ev = match run {
            Ok(r) => r,
            Err(payload) => Err(EvalError::panicked(format!(
                "evaluation #{ordinal} panicked: {}",
                panic_message(payload.as_ref())
            ))),
        };
        match (&ev, wall) {
            (Err(e), _) if e.kind.quarantined() => ev,
            (_, Some(limit)) if started.elapsed() > limit => {
                Err(EvalError::timeout(format!(
                    "evaluation #{ordinal} exceeded its {}ms wall budget ({}ms elapsed)",
                    limit.as_millis(),
                    started.elapsed().as_millis()
                )))
            }
            _ => ev,
        }
    }

    /// The miss path: compile through a shared transform prefix.
    /// Identical to [`evaluate_point`] by construction —
    /// `compile_staged` is `stage_prefix` + `compile_from_prefix` —
    /// but the prefix is computed once per (graph, vectorize, stream)
    /// choice and shared across candidates and worker threads.
    fn evaluate_uncached(
        &self,
        base: &BuildSpec,
        point: &DesignPoint,
        flops: f64,
    ) -> Result<Evaluation, EvalError> {
        let spec = point.apply_to(base);
        let key: PrefixKey = (spec.sdfg_fnv(), spec.vectorize.clone(), spec.stream);
        let prefix = {
            let cached = lock_unpoisoned(&self.prefixes).get(&key).cloned();
            match cached {
                Some(p) => {
                    if let Some(r) = self.probe() {
                        r.instant("prefix-cache-hit");
                    }
                    p
                }
                None => {
                    // computed outside the lock: two racing workers may
                    // both build it (deterministic, so identical); the
                    // first insert wins
                    let built = Arc::new(stage_prefix_observed(
                        &spec.sdfg,
                        &spec.vectorize,
                        spec.stream,
                        self.probe(),
                    ));
                    lock_unpoisoned(&self.prefixes)
                        .entry(key)
                        .or_insert_with(|| built.clone())
                        .clone()
                }
            }
        };
        let c = match prefix.as_ref() {
            Err(e) => return Err(classify(e.clone())),
            Ok(p) => compile_from_prefix_observed(p, &spec, self.probe()).map_err(classify)?,
        };
        design_rule_gate(&c)?;
        Ok(finish_evaluation(c, point, flops))
    }

    /// Evaluate a batch of candidates across OS threads. Results come
    /// back in input order; per-candidate failures (e.g. a binding that
    /// does not divide) are reported in place, not fatal. The whole
    /// batch reserves one contiguous ordinal block up front — input
    /// index `i` is always ordinal `start + i` — so fault injection
    /// stays deterministic regardless of worker interleaving.
    pub fn evaluate_all(
        &self,
        base: &BuildSpec,
        points: &[DesignPoint],
        flops: f64,
    ) -> Vec<Result<Evaluation, EvalError>> {
        let n = points.len();
        if n == 0 {
            return Vec::new();
        }
        let start = self.issued.fetch_add(n, Ordering::Relaxed);
        let workers = self.threads().min(n);
        let next = AtomicUsize::new(0);
        let slots: Mutex<Vec<Option<Result<Evaluation, EvalError>>>> =
            Mutex::new(vec![None; n]);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = self.evaluate_indexed(base, &points[i], flops, start + i);
                    lock_unpoisoned(&slots)[i] = Some(r);
                });
            }
        });
        slots
            .into_inner()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .into_iter()
            .map(|o| o.expect("every slot filled by a worker"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::coordinator::BuildSpec;
    use crate::dse::space::DesignPoint;

    fn vecadd_base() -> BuildSpec {
        BuildSpec::new(apps::vecadd::build()).bind("N", 1 << 14).seeded(7)
    }

    fn dp_point() -> DesignPoint {
        DesignPoint {
            vectorize: Some(("vadd".into(), 8)),
            pump: Some((2, crate::ir::PumpMode::Resource)),
            ..DesignPoint::original()
        }
    }

    #[test]
    fn cache_hit_returns_identical_report() {
        let ev = Evaluator::new();
        let base = vecadd_base();
        let flops = apps::vecadd::flops(1 << 14);
        let a = ev.evaluate(&base, &dp_point(), flops).unwrap();
        assert_eq!(ev.cache_misses(), 1);
        let b = ev.evaluate(&base, &dp_point(), flops).unwrap();
        assert_eq!(ev.cache_hits(), 1);
        // identical DesignReport, bit for bit
        assert_eq!(a.report.cl0.achieved_mhz, b.report.cl0.achieved_mhz);
        assert_eq!(
            a.report.cl1.map(|c| c.achieved_mhz),
            b.report.cl1.map(|c| c.achieved_mhz)
        );
        assert_eq!(a.report.resources, b.report.resources);
        assert_eq!(a.gops, b.gops);
        assert_eq!(a.resource_score, b.resource_score);
        // and the cached result equals a fresh out-of-cache evaluation
        let fresh = evaluate_point(&base, &dp_point(), flops).unwrap();
        assert_eq!(fresh.report.cl0.achieved_mhz, a.report.cl0.achieved_mhz);
        assert_eq!(fresh.slow_cycles, a.slow_cycles);
    }

    #[test]
    fn fingerprint_separates_points_and_seeds() {
        let base = vecadd_base();
        let o = DesignPoint::original();
        let f = apps::vecadd::flops(1 << 14);
        assert_ne!(fingerprint(&base, &o, f), fingerprint(&base, &dp_point(), f));
        let reseeded = vecadd_base().seeded(8);
        assert_ne!(fingerprint(&base, &o, f), fingerprint(&reseeded, &o, f));
    }

    #[test]
    fn fingerprint_separates_region_assignments() {
        use crate::ir::{PumpMode, RegionPump};
        let base = vecadd_base();
        let f = apps::vecadd::flops(1 << 14);
        let a = DesignPoint {
            regions: Some(vec![Some(RegionPump::resource(2)), Some(RegionPump::resource(4))]),
            ..DesignPoint::original()
        };
        let b = DesignPoint {
            regions: Some(vec![Some(RegionPump::resource(4)), Some(RegionPump::resource(2))]),
            ..DesignPoint::original()
        };
        let c = DesignPoint {
            regions: Some(vec![Some(RegionPump::resource(2)), None]),
            ..DesignPoint::original()
        };
        // same factors, different mode on one region: distinct content
        let d = DesignPoint {
            regions: Some(vec![
                Some(RegionPump::new(2, PumpMode::Throughput)),
                Some(RegionPump::resource(4)),
            ]),
            ..DesignPoint::original()
        };
        assert_ne!(fingerprint(&base, &a, f), fingerprint(&base, &b, f));
        assert_ne!(fingerprint(&base, &a, f), fingerprint(&base, &c, f));
        assert_ne!(fingerprint(&base, &a, f), fingerprint(&base, &d, f));
        assert_ne!(
            fingerprint(&base, &DesignPoint::original(), f),
            fingerprint(&base, &c, f)
        );
        // uniform bare-fast is distinct from uniform throughput at the
        // same factor
        let t = DesignPoint {
            pump: Some((2, PumpMode::Throughput)),
            ..DesignPoint::original()
        };
        let bf = DesignPoint {
            pump: Some((2, PumpMode::BareFast)),
            ..DesignPoint::original()
        };
        assert_ne!(fingerprint(&base, &t, f), fingerprint(&base, &bf, f));
    }

    #[test]
    fn contains_peeks_without_counting_hits() {
        let ev = Evaluator::new();
        let base = vecadd_base();
        let flops = apps::vecadd::flops(1 << 14);
        assert!(!ev.contains(&base, &dp_point(), flops));
        ev.evaluate(&base, &dp_point(), flops).unwrap();
        assert!(ev.contains(&base, &dp_point(), flops));
        assert_eq!(ev.cache_hits(), 0, "contains() must not count as a hit");
    }

    #[test]
    fn parallel_batch_matches_sequential() {
        let base = vecadd_base();
        let flops = apps::vecadd::flops(1 << 14);
        let points: Vec<DesignPoint> = [1usize, 2, 4, 8]
            .iter()
            .map(|&w| DesignPoint {
                vectorize: if w == 1 { None } else { Some(("vadd".into(), w)) },
                ..DesignPoint::original()
            })
            .collect();
        let par = Evaluator::new();
        let batch = par.evaluate_all(&base, &points, flops);
        assert_eq!(batch.len(), points.len());
        for (p, r) in points.iter().zip(&batch) {
            let seq = evaluate_point(&base, p, flops).unwrap();
            let got = r.as_ref().unwrap();
            assert_eq!(got.label, seq.label);
            assert_eq!(got.report.cl0.achieved_mhz, seq.report.cl0.achieved_mhz);
            assert_eq!(got.slow_cycles, seq.slow_cycles);
        }
    }

    #[test]
    fn pumped_vecadd_halves_dsp_and_holds_throughput() {
        let base = vecadd_base();
        let flops = apps::vecadd::flops(1 << 14);
        let o_point = DesignPoint {
            vectorize: Some(("vadd".into(), 8)),
            ..DesignPoint::original()
        };
        let o = evaluate_point(&base, &o_point, flops).unwrap();
        let dp = evaluate_point(&base, &dp_point(), flops).unwrap();
        assert!((dp.total_resources.dsp - o.total_resources.dsp / 2.0).abs() < 1e-9);
        let drift = (dp.time_s - o.time_s).abs() / o.time_s;
        assert!(drift < 0.2, "time drift {drift}");
        assert!(dp.resource_score < o.resource_score, "pumping must lower the resource axis");
        assert!(dp.fits && o.fits);
    }

    #[test]
    fn apply_to_shares_the_base_graph() {
        // zero-copy: instantiating a candidate over a base must not
        // deep-clone the SDFG — warm-cache candidates therefore clone
        // zero graph bytes end to end
        let base = vecadd_base();
        let spec = dp_point().apply_to(&base);
        assert!(std::sync::Arc::ptr_eq(&base.sdfg, &spec.sdfg));
        assert_eq!(base.sdfg_fnv(), spec.sdfg_fnv());
    }

    #[test]
    fn prefix_cache_is_per_vectorize_choice_not_per_candidate() {
        let ev = Evaluator::new();
        let base = vecadd_base();
        let flops = apps::vecadd::flops(1 << 14);
        // 6 candidates over 2 distinct vectorize choices
        let points: Vec<DesignPoint> = [
            (4usize, None),
            (4, Some((2, crate::ir::PumpMode::Resource))),
            (4, Some((4, crate::ir::PumpMode::Resource))),
            (8, None),
            (8, Some((2, crate::ir::PumpMode::Resource))),
            (8, Some((4, crate::ir::PumpMode::Resource))),
        ]
        .iter()
        .map(|(w, pump)| DesignPoint {
            vectorize: Some(("vadd".into(), *w)),
            pump: *pump,
            ..DesignPoint::original()
        })
        .collect();
        for r in ev.evaluate_all(&base, &points, flops) {
            r.unwrap();
        }
        assert_eq!(
            ev.prefix_entries(),
            2,
            "expected one shared prefix per vectorize choice"
        );
        // and the prefix-cached path matches the direct compile exactly
        let direct = evaluate_point(&base, &points[1], flops).unwrap();
        let cached = ev.evaluate(&base, &points[1], flops).unwrap();
        assert_eq!(direct.report.cl0.achieved_mhz, cached.report.cl0.achieved_mhz);
        assert_eq!(direct.slow_cycles, cached.slow_cycles);
        assert_eq!(direct.gops, cached.gops);
        assert_eq!(direct.resource_score, cached.resource_score);
    }

    #[test]
    fn arena_pool_reuses_one_arena_for_sequential_runs() {
        let pool = ArenaPool::default();
        assert_eq!(pool.pooled(), 0);
        let slots_first = pool.run(|a| {
            let t = a.alloc_from(&[1.0, 2.0]);
            a.free(t);
            a.stats().slots
        });
        assert_eq!(pool.pooled(), 1);
        // the second sequential run gets the same warmed arena back
        pool.run(|a| {
            assert_eq!(a.stats().slots, slots_first, "pool must hand back the warmed arena");
            let _ = a.alloc(2);
        });
        assert_eq!(pool.pooled(), 1, "sequential use must not grow the pool");
        let s = pool.stats();
        assert_eq!(s.slots, 1);
        assert!(s.recycle_hits >= 1);
        // telemetry counters: two checkouts, never more than one at once
        assert_eq!(pool.checkouts(), 2);
        assert_eq!(pool.peak_in_flight(), 1);
    }

    #[test]
    fn observed_evaluator_tags_candidate_outcomes() {
        use crate::telemetry::{Event, Recorder};
        let rec = Arc::new(Recorder::new());
        let ev = Evaluator::new().observed(rec.clone());
        let base = vecadd_base();
        let flops = apps::vecadd::flops(1 << 14);
        ev.evaluate(&base, &dp_point(), flops).unwrap(); // new compile
        ev.evaluate(&base, &dp_point(), flops).unwrap(); // memo hit
        let events = rec.events();
        let begins = |name: &str| {
            events
                .iter()
                .filter(|e| matches!(e, Event::Begin { name: n, .. } if n == name))
                .count()
        };
        assert_eq!(begins("dse.candidate"), 2);
        // the miss path ran the full staged compile under spans
        assert_eq!(begins("vectorize"), 1);
        assert_eq!(begins("pump"), 1);
        assert_eq!(begins("estimate"), 1);
        let outcomes: Vec<&str> = events
            .iter()
            .filter_map(|e| match e {
                Event::End { args, .. } => {
                    args.iter().find(|(k, _)| k == "outcome").map(|(_, v)| v.as_str())
                }
                _ => None,
            })
            .collect();
        assert_eq!(outcomes, vec!["new_compile", "memo_hit"]);
        // every candidate span carries its content fingerprint
        assert!(events.iter().any(|e| matches!(
            e,
            Event::End { args, .. } if args.iter().any(|(k, v)| k == "fingerprint" && v.len() == 16)
        )));
    }

    #[test]
    fn injected_panic_is_classified_quarantined_and_nonfatal() {
        let ev = Evaluator::new().with_faults(FaultPlan::parse("panic@0").unwrap());
        let base = vecadd_base();
        let flops = apps::vecadd::flops(1 << 14);
        let e = ev.evaluate(&base, &dp_point(), flops).unwrap_err();
        assert_eq!(e.kind, FailKind::Panic, "{e}");
        assert!(e.message.contains("#0"), "{e}");
        assert!(e.kind.quarantined());
        // quarantined: the retry is a memo hit, never a re-evaluation
        let again = ev.evaluate(&base, &dp_point(), flops).unwrap_err();
        assert_eq!(again.kind, FailKind::Panic);
        assert_eq!(ev.cache_hits(), 1);
        assert_eq!(ev.cache_misses(), 1);
        // no poisoned mutex, no leaked arena: the evaluator keeps going
        let ok = ev.evaluate(&base, &DesignPoint::original(), flops);
        assert!(ok.is_ok(), "evaluator dead after a caught panic: {ok:?}");
        ev.arenas().run(|a| {
            let t = a.alloc_from(&[1.0]);
            a.free(t);
        });
        assert_eq!(ev.faults().unwrap().fired(), 1);
    }

    #[test]
    fn slow_candidate_past_deadline_is_a_timeout() {
        let base = vecadd_base();
        let flops = apps::vecadd::flops(1 << 14);
        let ev = Evaluator::new().with_faults(FaultPlan::parse("slow@0").unwrap());
        ev.set_limits(Some(40), None);
        let e = ev.evaluate(&base, &dp_point(), flops).unwrap_err();
        assert_eq!(e.kind, FailKind::Timeout, "{e}");
        assert!(e.message.contains("wall budget"), "{e}");
        // the same injection with no armed deadline is benign
        let lax = Evaluator::new().with_faults(FaultPlan::parse("slow@0").unwrap());
        lax.evaluate(&base, &dp_point(), flops).unwrap();
    }

    #[test]
    fn wedged_candidate_is_reaped_as_timeout() {
        let base = vecadd_base();
        let flops = apps::vecadd::flops(1 << 14);
        let ev = Evaluator::new().with_faults(FaultPlan::parse("wedge@0").unwrap());
        ev.set_limits(Some(30), None);
        let e = ev.evaluate(&base, &dp_point(), flops).unwrap_err();
        assert_eq!(e.kind, FailKind::Timeout, "{e}");
        assert!(e.message.contains("wedged"), "{e}");
        // the wedge held the worker only until the deadline reaped it,
        // and the evaluator is still alive
        ev.evaluate(&base, &DesignPoint::original(), flops).unwrap();
    }

    #[test]
    fn limits_arm_and_clear_through_shared_ref() {
        let ev = Evaluator::new();
        assert_eq!(ev.wall_budget(), None);
        assert_eq!(ev.sim_cycle_budget(), crate::dse::verify::MAX_VERIFY_CYCLES);
        ev.set_limits(Some(250), Some(1_000));
        assert_eq!(ev.wall_budget(), Some(std::time::Duration::from_millis(250)));
        assert_eq!(ev.sim_cycle_budget(), 1_000);
        ev.set_limits(None, None);
        assert_eq!(ev.wall_budget(), None);
        assert_eq!(ev.sim_cycle_budget(), crate::dse::verify::MAX_VERIFY_CYCLES);
    }

    #[test]
    fn arena_pool_survives_a_panicking_run() {
        let pool = ArenaPool::default();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(|_a| {
                panic!("boom");
            });
        }));
        assert!(r.is_err());
        assert_eq!(pool.pooled(), 1, "arena must check back in on unwind");
        // no leaked in-flight slot, no poisoned lock: the pool still works
        pool.run(|a| {
            let t = a.alloc_from(&[1.0]);
            a.free(t);
        });
        assert_eq!(pool.checkouts(), 2);
        assert_eq!(pool.peak_in_flight(), 1, "panicking run leaked an in-flight slot");
    }

    #[test]
    fn observed_evaluator_tags_supervised_outcomes() {
        use crate::telemetry::{Event, Recorder};
        let rec = Arc::new(Recorder::new());
        let ev = Evaluator::new()
            .observed(rec.clone())
            .with_faults(FaultPlan::parse("panic@0,wedge@1").unwrap());
        ev.set_limits(Some(30), None);
        let base = vecadd_base();
        let flops = apps::vecadd::flops(1 << 14);
        let _ = ev.evaluate(&base, &dp_point(), flops);
        let _ = ev.evaluate(&base, &DesignPoint::original(), flops);
        let outcomes: Vec<String> = rec
            .events()
            .iter()
            .filter_map(|e| match e {
                Event::End { args, .. } => {
                    args.iter().find(|(k, _)| k == "outcome").map(|(_, v)| v.clone())
                }
                _ => None,
            })
            .collect();
        assert_eq!(outcomes, vec!["panic".to_string(), "timeout".to_string()]);
    }

    #[test]
    fn infeasible_binding_is_a_legality_error() {
        // N = 100 does not divide by 8: the candidate fails cleanly,
        // and the failure is classified legality — not a compile error
        let base = BuildSpec::new(apps::vecadd::build()).bind("N", 100);
        let ev = Evaluator::new();
        let r = ev.evaluate(&base, &dp_point(), 100.0);
        let e = r.unwrap_err();
        assert_eq!(e.kind, FailKind::Legality, "{e}");
        // the cached failure keeps its kind
        let again = ev.evaluate(&base, &dp_point(), 100.0).unwrap_err();
        assert_eq!(again.kind, FailKind::Legality);
        assert_eq!(ev.cache_hits(), 1);
    }
}
