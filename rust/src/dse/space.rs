//! Candidate-grid generation: the legal (spatial × temporal) design
//! space of one SDFG.
//!
//! The grid is driven by the same analyses the transformations use —
//! [`crate::analysis::vectorizability`] for legal vector widths and
//! temporal legality, container lane counts for pump-factor
//! divisibility — rather than brute-force enumeration, so illegal
//! points (a pump factor that does not divide the vectorized stream
//! width, resource-mode pumping of an unvectorizable scalar datapath,
//! more replicas than the device has SLRs) are pruned before a single
//! compile runs. Floyd–Warshall therefore only ever receives
//! throughput-mode candidates, exactly the paper's §4.4 argument.

use crate::analysis::movement::scope_movement;
use crate::analysis::streamability::partition_streamable;
use crate::analysis::vectorizability::{check_temporal, check_traditional};
use crate::coordinator::pipeline::BuildSpec;
use crate::hw::Device;
use crate::ir::{ContainerKind, LibraryOp, Node, PumpMode, RegionPump, Sdfg};
use crate::symbolic::SymbolTable;
use crate::transforms::multipump::assignment_label;

/// One candidate configuration of the compile pipeline. The point owns
/// the dimensions the search explores; everything else (bindings, seed,
/// base clock request) is inherited from the base [`BuildSpec`].
#[derive(Clone, Debug, PartialEq)]
pub struct DesignPoint {
    /// Traditional vectorization of a named map, if any.
    pub vectorize: Option<(String, usize)>,
    /// Uniform multi-pumping (factor, mode), if any.
    pub pump: Option<(usize, PumpMode)>,
    /// Mixed per-region pump assignment (one `RegionPump` per
    /// streamable region in partition order; `None` stays in CL0).
    /// Each region carries its own factor *and* mode. Mutually
    /// exclusive with `pump`.
    pub regions: Option<Vec<Option<RegionPump>>>,
    /// SLR replication count (≥ 1).
    pub replicas: usize,
    /// CL0 request override in MHz (None → keep the base spec's).
    pub cl0_request_mhz: Option<f64>,
}

impl DesignPoint {
    /// The unpumped, unreplicated origin of the space.
    pub fn original() -> DesignPoint {
        DesignPoint {
            vectorize: None,
            pump: None,
            regions: None,
            replicas: 1,
            cl0_request_mhz: None,
        }
    }

    /// Compact label, e.g. `V8 R2`, `O`, `T2 x3SLR`, `B2`,
    /// `Mx[t2x1+2x3]`.
    pub fn label(&self) -> String {
        let mut s = String::new();
        if let Some((_, w)) = &self.vectorize {
            s.push_str(&format!("V{w} "));
        }
        match (&self.regions, self.pump) {
            (Some(fs), _) => s.push_str(&format!("Mx[{}]", assignment_label(fs))),
            (None, None) => s.push('O'),
            (None, Some((f, PumpMode::Resource))) => s.push_str(&format!("R{f}")),
            (None, Some((f, PumpMode::Throughput))) => s.push_str(&format!("T{f}")),
            (None, Some((f, PumpMode::BareFast))) => s.push_str(&format!("B{f}")),
        }
        if self.replicas > 1 {
            s.push_str(&format!(" x{}SLR", self.replicas));
        }
        if let Some(mhz) = self.cl0_request_mhz {
            s.push_str(&format!(" @{mhz:.0}"));
        }
        s
    }

    /// Instantiate the candidate over a base spec. The point owns the
    /// vectorize / pump / replica dimensions and overwrites them even
    /// when `None`; bindings, seed and streaming are inherited.
    pub fn apply_to(&self, base: &BuildSpec) -> BuildSpec {
        let mut spec = base.clone();
        spec.vectorize = self.vectorize.clone();
        spec.pump = self.pump;
        spec.pump_regions = self.regions.clone();
        spec.slr_replicas = self.replicas;
        if self.cl0_request_mhz.is_some() {
            spec.cl0_request_mhz = self.cl0_request_mhz;
        }
        spec
    }
}

/// Bounds of the candidate grid.
#[derive(Clone, Debug)]
pub struct SpaceOptions {
    /// Vector widths to probe per vectorizable map.
    pub vector_widths: Vec<usize>,
    /// Pump factors to probe (each mode separately).
    pub pump_factors: Vec<usize>,
    /// Pump modes to probe. Restricting to one mode is useful because
    /// the modes are duals (throughput-pumping V=4 lowers to the same
    /// netlist as resource-pumping V=8): a Table-2-style resource
    /// study explores `[Resource]` only.
    pub pump_modes: Vec<PumpMode>,
    /// Maximum SLR replication (≥ 1).
    pub max_replicas: usize,
    /// Extra CL0 requests to probe besides the base spec's.
    pub cl0_requests_mhz: Vec<f64>,
    /// Also enumerate *mixed* per-region pump assignments: two-block
    /// contiguous splits of the region chain, each block at its own
    /// `RegionPump` — factor *and* mode, drawn from `pump_modes` and
    /// pruned per-region (resource → width divisibility, throughput →
    /// external feed, bare-fast → dependent pipeline) — or unpumped.
    /// Off by default — the dimension multiplies the grid on
    /// multi-region graphs.
    pub mixed_factors: bool,
}

impl SpaceOptions {
    /// Defaults bounded by the device: replicas up to the SLR count.
    pub fn for_device(device: &Device) -> SpaceOptions {
        SpaceOptions {
            vector_widths: vec![2, 4, 8, 16],
            pump_factors: vec![2, 4, 8],
            pump_modes: vec![PumpMode::Resource, PumpMode::Throughput],
            max_replicas: device.slrs.len().max(1),
            cl0_requests_mhz: Vec::new(),
            mixed_factors: false,
        }
    }
}

/// Environment from the base spec's concrete bindings.
fn base_env(base: &BuildSpec) -> SymbolTable {
    let mut env = SymbolTable::new();
    for (s, v) in &base.bindings {
        env.set(s, *v);
    }
    env
}

/// Legal `(map name, width)` vectorization options (plus `None`),
/// established with the traditional SIMD conditions and a concrete
/// trip-count divisibility check against the base bindings.
fn vector_options(
    g: &Sdfg,
    env: &SymbolTable,
    widths: &[usize],
) -> Vec<Option<(String, usize)>> {
    let mut out: Vec<Option<(String, usize)>> = vec![None];
    for id in g.node_ids() {
        let name = match g.node(id) {
            Node::MapEntry { name, .. } => name.clone(),
            _ => continue,
        };
        let mv = match scope_movement(g, id) {
            Ok(mv) => mv,
            Err(_) => continue,
        };
        // the strict conditions minus divisibility (factor 1), exactly
        // as Vectorize::can_apply establishes them
        if !check_traditional(g, &mv, 1, env).is_ok() {
            continue;
        }
        // unit-stride accesses only (stride-V cannot be re-vectorized)
        if mv
            .all()
            .any(|acc| acc.subset.linear_in(mv.inner_param()) != Some(1))
        {
            continue;
        }
        let trip = match g.node(id) {
            Node::MapEntry { ranges, .. } => {
                ranges.last().and_then(|r| r.count(env))
            }
            _ => None,
        };
        for &w in widths {
            if w < 2 {
                continue;
            }
            // concrete extent must divide; symbolic extents defer to
            // the derived-symbol check at bind time and are accepted
            if let Some(t) = trip {
                if t % w as i64 != 0 {
                    continue;
                }
            }
            out.push(Some((name.clone(), w)));
        }
    }
    out
}

/// The narrowest stream width the streamed design will carry under a
/// given vectorization choice: external array lanes (vectorization
/// widens every container the map touches) and fused transient arrays.
fn boundary_width(g: &Sdfg, vectorize: &Option<(String, usize)>) -> usize {
    let vw = vectorize.as_ref().map(|(_, w)| *w).unwrap_or(1);
    let mut min_lanes = usize::MAX;
    for decl in g.containers.values() {
        if decl.kind == ContainerKind::Array {
            min_lanes = min_lanes.min(decl.vtype.lanes);
        }
    }
    if min_lanes == usize::MAX {
        min_lanes = 1;
    }
    min_lanes * vw
}

/// Is every map scope temporally vectorizable (the multi-pumping
/// precondition)? Graphs whose compute lives in library nodes pass
/// vacuously, mirroring `MultiPump::can_apply`.
fn temporally_legal(g: &Sdfg) -> bool {
    for id in g.node_ids() {
        if matches!(g.node(id), Node::MapEntry { .. }) {
            match scope_movement(g, id) {
                Ok(mv) => {
                    if !check_temporal(g, &mv, 1).is_ok() {
                        return false;
                    }
                }
                Err(_) => return false,
            }
        }
    }
    true
}

/// Do all library datapaths keep an integer lane count at factor `m`?
fn library_widths_divide(g: &Sdfg, m: usize) -> bool {
    for id in g.node_ids() {
        if let Node::Library { op, .. } = g.node(id) {
            let w = match op {
                LibraryOp::SystolicGemm { vec_width, .. }
                | LibraryOp::StencilStage { vec_width, .. } => *vec_width,
                // FW keeps its datapath width in resource mode
                LibraryOp::FloydWarshall { .. } => continue,
            };
            if w % m != 0 {
                return false;
            }
        }
    }
    true
}

/// Legal pump options (plus `None`) for one vectorization choice.
fn pump_options(
    g: &Sdfg,
    vectorize: &Option<(String, usize)>,
    opts: &SpaceOptions,
) -> Vec<Option<(usize, PumpMode)>> {
    let mut out: Vec<Option<(usize, PumpMode)>> = vec![None];
    if !temporally_legal(g) {
        return out;
    }
    let width = boundary_width(g, vectorize);
    // bare-fast is a whole-graph property here: the faster clock only
    // recovers something when every streamable region pipelines at
    // II > 1 (mirrors `MultiPump::can_apply` for uniform bare-fast)
    let all_dependent = {
        let regions = partition_streamable(g);
        !regions.is_empty() && regions.iter().all(|r| r.dependent)
    };
    for &m in &opts.pump_factors {
        if m < 2 {
            continue;
        }
        // resource mode: the internal width must divide by M
        if opts.pump_modes.contains(&PumpMode::Resource)
            && width % m == 0
            && width / m >= 1
            && library_widths_divide(g, m)
        {
            out.push(Some((m, PumpMode::Resource)));
        }
        // throughput mode widens the boundary instead — always legal
        if opts.pump_modes.contains(&PumpMode::Throughput) {
            out.push(Some((m, PumpMode::Throughput)));
        }
        // bare-fast: unchanged widths, zero gearboxes — legal only on
        // dependent (II > 1) pipelines
        if opts.pump_modes.contains(&PumpMode::BareFast) && all_dependent {
            out.push(Some((m, PumpMode::BareFast)));
        }
    }
    out
}

/// Mixed per-region assignments: for every split point of the region
/// chain, a prefix `RegionPump` and a suffix `RegionPump` (each a
/// legality-pruned {factor, mode} of that block's regions, or `None` =
/// CL0), prefix ≠ suffix. Equal-pump blocks cluster contiguously
/// because every extra domain change along the chain pays a crossing —
/// and the anneal walk can still reach any other assignment through
/// single-region mutations. Pure-uniform assignments are omitted: they
/// are exactly the legacy `pump` axis.
fn mixed_options(g: &Sdfg, opts: &SpaceOptions) -> Vec<Vec<Option<RegionPump>>> {
    if !opts.mixed_factors {
        return Vec::new();
    }
    let regions = partition_streamable(g);
    if regions.len() < 2 {
        return Vec::new();
    }
    // per-region legal pumps: per-mode legality (resource → width
    // divisibility, throughput → external feed, bare-fast → II > 1)
    // plus the temporal check for map-anchored regions
    let legal: Vec<Vec<RegionPump>> = regions
        .iter()
        .map(|r| {
            if matches!(g.node(r.module), Node::MapEntry { .. }) {
                let temporal_ok = scope_movement(g, r.module)
                    .map(|mv| check_temporal(g, &mv, 1).is_ok())
                    .unwrap_or(false);
                if !temporal_ok {
                    return Vec::new();
                }
            }
            r.legal_pumps(&opts.pump_factors, &opts.pump_modes)
        })
        .collect();
    // pumps legal on a whole contiguous block
    let block_options = |range: std::ops::Range<usize>| -> Vec<Option<RegionPump>> {
        let mut out: Vec<Option<RegionPump>> = vec![None];
        for &mode in &opts.pump_modes {
            for &f in &opts.pump_factors {
                let p = RegionPump::new(f, mode);
                if f >= 2 && legal[range.clone()].iter().all(|l| l.contains(&p)) {
                    out.push(Some(p));
                }
            }
        }
        out
    };
    let compatible = |a: Option<RegionPump>, b: Option<RegionPump>| match (a, b) {
        // fast domains must share one fast time base
        (Some(x), Some(y)) => {
            x.factor.max(y.factor) % x.factor.min(y.factor) == 0
        }
        _ => true,
    };
    let mut out = Vec::new();
    for split in 1..regions.len() {
        for &a in &block_options(0..split) {
            for &b in &block_options(split..regions.len()) {
                if a == b || !compatible(a, b) || (a.is_none() && b.is_none()) {
                    continue;
                }
                let mut v = vec![a; split];
                v.extend(std::iter::repeat(b).take(regions.len() - split));
                out.push(v);
            }
        }
    }
    // adjacent splits can coincide when a block option vanishes
    out.sort();
    out.dedup();
    out
}

/// Generate the pruned candidate grid for a base spec on a device.
pub fn generate(base: &BuildSpec, _device: &Device, opts: &SpaceOptions) -> Vec<DesignPoint> {
    let g = &base.sdfg;
    let env = base_env(base);
    let mut cl0s: Vec<Option<f64>> = vec![None];
    for &mhz in &opts.cl0_requests_mhz {
        cl0s.push(Some(mhz));
    }
    let mut out = Vec::new();
    for vec_opt in vector_options(g, &env, &opts.vector_widths) {
        for pump_opt in pump_options(g, &vec_opt, opts) {
            for replicas in 1..=opts.max_replicas.max(1) {
                for cl0 in &cl0s {
                    out.push(DesignPoint {
                        vectorize: vec_opt.clone(),
                        pump: pump_opt,
                        regions: None,
                        replicas,
                        cl0_request_mhz: *cl0,
                    });
                }
            }
        }
    }
    // the mixed per-region axis rides alongside the uniform pump axis
    // (unvectorized: the multi-region apps are library chains)
    for assignment in mixed_options(g, opts) {
        for replicas in 1..=opts.max_replicas.max(1) {
            for cl0 in &cl0s {
                out.push(DesignPoint {
                    vectorize: None,
                    pump: None,
                    regions: Some(assignment.clone()),
                    replicas,
                    cl0_request_mhz: *cl0,
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::coordinator::BuildSpec;

    fn space_for(spec: &BuildSpec) -> Vec<DesignPoint> {
        let device = Device::u280();
        let opts = SpaceOptions::for_device(&device);
        generate(spec, &device, &opts)
    }

    #[test]
    fn vecadd_space_has_vector_and_pump_axes() {
        let spec = BuildSpec::new(apps::vecadd::build()).bind("N", 1 << 16);
        let points = space_for(&spec);
        // contains the paper's Table 2 double-pumped configuration
        assert!(points.iter().any(|p| {
            p.vectorize == Some(("vadd".into(), 8))
                && p.pump == Some((2, PumpMode::Resource))
                && p.replicas == 1
        }));
        // the original is always present
        assert!(points.contains(&DesignPoint::original()));
        // every resource-mode factor divides its vector width
        for p in &points {
            if let Some((m, PumpMode::Resource)) = p.pump {
                let w = p.vectorize.as_ref().map(|(_, w)| *w).unwrap_or(1);
                assert_eq!(w % m, 0, "illegal point {}", p.label());
            }
        }
        // replicas bounded by the SLR count
        assert!(points.iter().all(|p| (1..=3).contains(&p.replicas)));
    }

    #[test]
    fn indivisible_trip_count_prunes_widths() {
        // N = 20: widths 2 and 4 divide, 8 and 16 do not
        let spec = BuildSpec::new(apps::vecadd::build()).bind("N", 20);
        let points = space_for(&spec);
        let widths: Vec<usize> = points
            .iter()
            .filter_map(|p| p.vectorize.as_ref().map(|(_, w)| *w))
            .collect();
        assert!(widths.contains(&2) && widths.contains(&4));
        assert!(!widths.contains(&8), "w=8 must be pruned for N=20");
        assert!(!widths.contains(&16));
    }

    #[test]
    fn floyd_warshall_space_is_throughput_only() {
        // FW: scalar boundary stream, dependent datapath — resource
        // mode is illegal, throughput mode is the paper's §4.4 choice
        let spec = BuildSpec::new(apps::floyd_warshall::build()).bind("N", 64);
        let points = space_for(&spec);
        assert!(!points.is_empty());
        assert!(points
            .iter()
            .all(|p| !matches!(p.pump, Some((_, PumpMode::Resource)))));
        assert!(points
            .iter()
            .any(|p| matches!(p.pump, Some((2, PumpMode::Throughput)))));
        // no maps → no vectorization options
        assert!(points.iter().all(|p| p.vectorize.is_none()));
    }

    #[test]
    fn matmul_space_prunes_by_library_width() {
        let mut spec = BuildSpec::new(apps::matmul::build(8));
        for (s, v) in apps::matmul::bindings(256) {
            spec = spec.bind(&s, v);
        }
        let points = space_for(&spec);
        // vec width is 16: resource factors 2, 4, 8 all divide
        for m in [2usize, 4, 8] {
            assert!(
                points
                    .iter()
                    .any(|p| p.pump == Some((m, PumpMode::Resource))),
                "missing R{m}"
            );
        }
    }

    #[test]
    fn labels_are_compact_and_distinct() {
        let a = DesignPoint::original();
        assert_eq!(a.label(), "O");
        let b = DesignPoint {
            vectorize: Some(("vadd".into(), 8)),
            pump: Some((2, PumpMode::Resource)),
            regions: None,
            replicas: 3,
            cl0_request_mhz: None,
        };
        assert_eq!(b.label(), "V8 R2 x3SLR");
        let c = DesignPoint { pump: Some((4, PumpMode::Throughput)), ..a.clone() };
        assert_eq!(c.label(), "T4");
        let bf = DesignPoint { pump: Some((2, PumpMode::BareFast)), ..a.clone() };
        assert_eq!(bf.label(), "B2");
        let m = DesignPoint {
            regions: Some(vec![
                Some(RegionPump::resource(4)),
                Some(RegionPump::resource(4)),
                Some(RegionPump::resource(2)),
                None,
            ]),
            ..a.clone()
        };
        assert_eq!(m.label(), "Mx[4x2+2x1+-x1]");
        let mm = DesignPoint {
            regions: Some(vec![
                Some(RegionPump::new(2, PumpMode::Throughput)),
                Some(RegionPump::resource(2)),
            ]),
            ..a.clone()
        };
        assert_eq!(mm.label(), "Mx[t2x1+2x1]");
    }

    #[test]
    fn stencil_space_gains_mixed_assignments_when_enabled() {
        let mut spec = BuildSpec::new(apps::stencil::build(
            crate::ir::StencilKind::Jacobi3D,
            4,
            8,
        ));
        for (s, v) in [("NX", 64i64), ("NY", 32), ("NZ", 32), ("NZ_v", 4)] {
            spec = spec.bind(s, v);
        }
        let device = Device::u280();
        let mut opts = SpaceOptions::for_device(&device);
        opts.max_replicas = 1;
        // off by default: no mixed points
        assert!(generate(&spec, &device, &opts).iter().all(|p| p.regions.is_none()));
        opts.mixed_factors = true;
        let points = generate(&spec, &device, &opts);
        let mixed: Vec<&DesignPoint> = points.iter().filter(|p| p.regions.is_some()).collect();
        assert!(!mixed.is_empty(), "mixed dimension produced no candidates");
        for p in &mixed {
            let fs = p.regions.as_ref().unwrap();
            assert_eq!(fs.len(), 4, "assignment must cover every region: {}", p.label());
            // legality: every resource-mode factor divides the stage
            // width 8; stencil stages pipeline at II = 1 so bare-fast
            // never appears
            assert!(
                fs.iter().flatten().all(|p| match p.mode {
                    PumpMode::Resource => 8 % p.factor == 0,
                    PumpMode::Throughput => true,
                    PumpMode::BareFast => false,
                }),
                "{}",
                p.label()
            );
            // not a pure-uniform assignment (those live on the pump axis)
            assert!(
                !(fs.iter().all(|f| f.is_some()) && fs.windows(2).all(|w| w[0] == w[1])),
                "uniform assignment duplicated on the mixed axis: {}",
                p.label()
            );
            assert!(fs.iter().any(|f| f.is_some()));
        }
        // the canonical half/half split is present
        assert!(mixed.iter().any(|p| {
            p.regions.as_ref().unwrap()
                == &vec![
                    Some(RegionPump::resource(4)),
                    Some(RegionPump::resource(4)),
                    Some(RegionPump::resource(2)),
                    Some(RegionPump::resource(2)),
                ]
        }));
        // and the mode axis is explored: a throughput head block over a
        // resource tail (region 0 touches the external input stream)
        assert!(
            mixed.iter().any(|p| {
                let fs = p.regions.as_ref().unwrap();
                fs[0].map(|p| p.mode) == Some(PumpMode::Throughput)
                    && fs
                        .iter()
                        .skip(1)
                        .flatten()
                        .any(|p| p.mode == PumpMode::Resource)
            }),
            "no throughput/resource mixed-mode assignment enumerated"
        );
    }

    #[test]
    fn mixed_assignments_prune_per_region_legality() {
        // desynchronize one stage's datapath width: factors that do not
        // divide it must vanish from every assignment touching that region
        let mut g = apps::stencil::build(crate::ir::StencilKind::Jacobi3D, 4, 8);
        for id in g.node_ids().collect::<Vec<_>>() {
            if let Node::Library {
                op: LibraryOp::StencilStage { vec_width, .. },
                name,
            } = g.node_mut(id)
            {
                if name.ends_with("stage3") {
                    *vec_width = 2;
                }
            }
        }
        let mut spec = BuildSpec::new(g);
        for (s, v) in [("NX", 64i64), ("NY", 32), ("NZ", 32), ("NZ_v", 4)] {
            spec = spec.bind(s, v);
        }
        let device = Device::u280();
        let mut opts = SpaceOptions::for_device(&device);
        opts.max_replicas = 1;
        opts.mixed_factors = true;
        let points = generate(&spec, &device, &opts);
        for p in points.iter().filter(|p| p.regions.is_some()) {
            let fs = p.regions.as_ref().unwrap();
            assert!(
                fs[3]
                    .map(|p| p.mode != PumpMode::Resource || 2 % p.factor == 0)
                    .unwrap_or(true),
                "region 3 (width 2) got an illegal resource factor: {}",
                p.label()
            );
        }
    }

    #[test]
    fn floyd_warshall_space_gains_barefast_when_requested() {
        let spec = BuildSpec::new(apps::floyd_warshall::build()).bind("N", 64);
        let device = Device::u280();
        let mut opts = SpaceOptions::for_device(&device);
        // default mode set: no bare-fast points
        assert!(generate(&spec, &device, &opts)
            .iter()
            .all(|p| !matches!(p.pump, Some((_, PumpMode::BareFast)))));
        opts.pump_modes = vec![PumpMode::Throughput, PumpMode::BareFast];
        let points = generate(&spec, &device, &opts);
        // FW's datapath is dependent (II > 1): bare-fast is legal
        assert!(points
            .iter()
            .any(|p| p.pump == Some((2, PumpMode::BareFast))));
    }

    #[test]
    fn stencil_space_never_offers_barefast() {
        // stencil stages pipeline at II = 1 — the faster clock would
        // recover nothing, so the axis prunes bare-fast entirely
        let mut spec = BuildSpec::new(apps::stencil::build(
            crate::ir::StencilKind::Jacobi3D,
            4,
            8,
        ));
        for (s, v) in [("NX", 64i64), ("NY", 32), ("NZ", 32), ("NZ_v", 4)] {
            spec = spec.bind(s, v);
        }
        let device = Device::u280();
        let mut opts = SpaceOptions::for_device(&device);
        opts.pump_modes =
            vec![PumpMode::Resource, PumpMode::Throughput, PumpMode::BareFast];
        let points = generate(&spec, &device, &opts);
        assert!(points
            .iter()
            .all(|p| !matches!(p.pump, Some((_, PumpMode::BareFast)))));
    }
}
