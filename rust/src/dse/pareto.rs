//! Resource-vs-throughput Pareto analysis over evaluated candidates.
//!
//! The two axes generalize the paper's two pumping modes into search
//! objectives (§2.1): *resource mode* is "minimum resource at
//! iso-throughput", *throughput mode* is "maximum throughput at
//! iso-resource". The resource axis is a scalar blend of the
//! [`DesignReport`](crate::codegen::DesignReport) utilization classes,
//! weighted toward the compute resources the paper's headline results
//! are about (DSP first, BRAM second, fabric third).

use std::cmp::Ordering;

use crate::hw::Utilization;

use super::evaluate::Evaluation;

/// Scalar resource score of one replica in [0, ~1]: DSP-dominant blend
/// of the utilization classes (DSP / BRAM / LUT+register fabric). The
/// weighting makes the paper's halved-DSP configurations strictly
/// cheaper than their originals even when the design is BRAM- or
/// fabric-bound overall.
pub fn resource_score(u: &Utilization) -> f64 {
    0.70 * u.dsp + 0.20 * u.bram + 0.10 * u.fabric_pressure()
}

/// Are both Pareto metrics finite? A candidate with a NaN/∞ `gops` or
/// resource score (a degenerate rate-model or report) can neither be
/// ranked nor meaningfully dominate anything: `partial_cmp` on NaN
/// answers `None`, which used to default to `Equal` and let a poisoned
/// candidate survive into — or scramble — the frontier. Every ranking
/// entry point filters on this first.
pub fn finite_metrics(e: &Evaluation) -> bool {
    e.gops.is_finite() && e.resource_score.is_finite()
}

/// Does `a` Pareto-dominate `b`? No worse on both axes and strictly
/// better on at least one. Nothing with a non-finite metric dominates
/// or is dominated — such candidates are filtered out before ranking.
pub fn dominates(a: &Evaluation, b: &Evaluation) -> bool {
    if !finite_metrics(a) || !finite_metrics(b) {
        return false;
    }
    let no_worse = a.resource_score <= b.resource_score && a.gops >= b.gops;
    let strictly = a.resource_score < b.resource_score || a.gops > b.gops;
    no_worse && strictly
}

/// Non-dominated subset of the fitting, finite-metric candidates, in a
/// stable, deterministic order: ascending resource score, then
/// descending throughput, then label.
pub fn frontier(evals: &[Evaluation]) -> Vec<Evaluation> {
    let fitting: Vec<Evaluation> =
        evals.iter().filter(|e| e.fits && finite_metrics(e)).cloned().collect();
    let mut out: Vec<Evaluation> = Vec::new();
    for e in &fitting {
        if !fitting.iter().any(|o| dominates(o, e)) {
            out.push(e.clone());
        }
    }
    out.sort_by(cmp_frontier);
    out.dedup_by(|a, b| a.label == b.label);
    out
}

fn cmp_frontier(a: &Evaluation, b: &Evaluation) -> Ordering {
    a.resource_score
        .partial_cmp(&b.resource_score)
        .unwrap_or(Ordering::Equal)
        .then(b.gops.partial_cmp(&a.gops).unwrap_or(Ordering::Equal))
        .then(a.label.cmp(&b.label))
}

/// A search objective: which end of the frontier to walk to.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Objective {
    /// Minimize the resource score subject to
    /// `throughput ≥ (1 − tolerance) × reference` — the generalized
    /// *resource* pumping mode.
    MinResourceAtIsoThroughput { tolerance: f64 },
    /// Maximize throughput subject to
    /// `resource ≤ (1 + tolerance) × reference` — the generalized
    /// *throughput* pumping mode.
    MaxThroughputAtIsoResource { tolerance: f64 },
}

impl Objective {
    /// Default resource objective: 20 % throughput slack, matching the
    /// paper's observed DP-vs-O drift (Table 3: DP-32 reaches 85 % of
    /// O-32 throughput at half the DSPs).
    pub fn resource() -> Objective {
        Objective::MinResourceAtIsoThroughput { tolerance: 0.20 }
    }

    /// Default throughput objective: 10 % resource slack.
    pub fn throughput() -> Objective {
        Objective::MaxThroughputAtIsoResource { tolerance: 0.10 }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Objective::MinResourceAtIsoThroughput { .. } => "min-resource @ iso-throughput",
            Objective::MaxThroughputAtIsoResource { .. } => "max-throughput @ iso-resource",
        }
    }

    /// Does a candidate satisfy the iso-constraint against the
    /// reference (the best unpumped single-replica design)? A
    /// non-finite metric is never feasible.
    pub fn feasible(&self, e: &Evaluation, reference: &Evaluation) -> bool {
        if !e.fits || !finite_metrics(e) {
            return false;
        }
        match self {
            Objective::MinResourceAtIsoThroughput { tolerance } => {
                e.gops >= reference.gops * (1.0 - tolerance)
            }
            Objective::MaxThroughputAtIsoResource { tolerance } => {
                e.resource_score <= reference.resource_score * (1.0 + tolerance)
            }
        }
    }

    /// Rank key (lower is better): feasible candidates first, ordered
    /// by the objective metric; infeasible ones ordered by how close
    /// they are to feasibility, so greedy search can climb toward the
    /// feasible region. A non-finite metric ranks last, deterministically.
    pub fn rank(&self, e: &Evaluation, reference: &Evaluation) -> (u8, f64) {
        let finite = |m: f64| if m.is_finite() { m } else { f64::INFINITY };
        let feasible = self.feasible(e, reference);
        match self {
            Objective::MinResourceAtIsoThroughput { .. } => {
                if feasible {
                    (0, finite(e.resource_score))
                } else {
                    (1, finite(-e.gops))
                }
            }
            Objective::MaxThroughputAtIsoResource { .. } => {
                if feasible {
                    (0, finite(-e.gops))
                } else {
                    (1, finite(e.resource_score))
                }
            }
        }
    }

    /// Pick the best feasible candidate (deterministic: rank, then
    /// label). None when nothing satisfies the constraint.
    pub fn select<'a>(
        &self,
        evals: &'a [Evaluation],
        reference: &Evaluation,
    ) -> Option<&'a Evaluation> {
        evals
            .iter()
            .filter(|e| self.feasible(e, reference))
            .min_by(|a, b| {
                let (ra, rb) = (self.rank(a, reference), self.rank(b, reference));
                ra.0.cmp(&rb.0)
                    .then(ra.1.partial_cmp(&rb.1).unwrap_or(Ordering::Equal))
                    .then(a.label.cmp(&b.label))
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::coordinator::BuildSpec;
    use crate::dse::evaluate::evaluate_point;
    use crate::dse::space::DesignPoint;

    /// A real evaluation with the Pareto axes overridden, so dominance
    /// patterns can be crafted exactly.
    fn ev(label: &str, score: f64, gops: f64) -> Evaluation {
        let base = BuildSpec::new(apps::vecadd::build()).bind("N", 1 << 10);
        let mut e =
            evaluate_point(&base, &DesignPoint::original(), apps::vecadd::flops(1 << 10))
                .unwrap();
        e.label = label.to_string();
        e.resource_score = score;
        e.gops = gops;
        e.fits = true;
        e
    }

    #[test]
    fn dominated_points_removed() {
        let evals = vec![
            ev("cheap-slow", 0.2, 10.0),
            ev("mid", 0.5, 50.0),
            ev("dominated", 0.6, 40.0), // worse than "mid" on both axes
            ev("fast-costly", 0.9, 90.0),
        ];
        let f = frontier(&evals);
        let labels: Vec<&str> = f.iter().map(|e| e.label.as_str()).collect();
        assert_eq!(labels, vec!["cheap-slow", "mid", "fast-costly"]);
    }

    #[test]
    fn frontier_order_is_stable_and_sorted() {
        let evals = vec![
            ev("b", 0.5, 50.0),
            ev("a", 0.2, 10.0),
            ev("c", 0.9, 90.0),
        ];
        let f1 = frontier(&evals);
        let mut reversed = evals.clone();
        reversed.reverse();
        let f2 = frontier(&reversed);
        let l1: Vec<&str> = f1.iter().map(|e| e.label.as_str()).collect();
        let l2: Vec<&str> = f2.iter().map(|e| e.label.as_str()).collect();
        assert_eq!(l1, l2, "order must not depend on input order");
        assert_eq!(l1, vec!["a", "b", "c"]);
        // ascending resource score
        assert!(f1.windows(2).all(|w| w[0].resource_score <= w[1].resource_score));
    }

    #[test]
    fn equal_points_both_survive() {
        // neither strictly dominates the other
        let evals = vec![ev("x", 0.5, 50.0), ev("y", 0.5, 50.0)];
        assert_eq!(frontier(&evals).len(), 2);
        assert!(!dominates(&evals[0], &evals[1]));
    }

    #[test]
    fn non_fitting_points_excluded() {
        let mut big = ev("too-big", 0.1, 999.0);
        big.fits = false;
        let evals = vec![big, ev("ok", 0.5, 50.0)];
        let f = frontier(&evals);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].label, "ok");
    }

    #[test]
    fn resource_objective_selects_cheapest_feasible() {
        let reference = ev("ref", 0.8, 100.0);
        let evals = vec![
            ev("half-dsp", 0.4, 90.0),     // feasible at tol 0.2, cheapest
            ev("quarter-dsp", 0.2, 60.0),  // cheaper but too slow
            reference.clone(),
        ];
        let obj = Objective::resource();
        let chosen = obj.select(&evals, &reference).unwrap();
        assert_eq!(chosen.label, "half-dsp");
    }

    #[test]
    fn throughput_objective_selects_fastest_within_budget() {
        let reference = ev("ref", 0.5, 100.0);
        let evals = vec![
            ev("fast-within", 0.54, 150.0), // within 10 % resource slack
            ev("faster-over", 0.9, 300.0),  // over budget
            reference.clone(),
        ];
        let obj = Objective::throughput();
        let chosen = obj.select(&evals, &reference).unwrap();
        assert_eq!(chosen.label, "fast-within");
    }

    #[test]
    fn select_is_none_when_nothing_feasible() {
        let reference = ev("ref", 0.8, 100.0);
        let evals = vec![ev("slow", 0.1, 10.0)];
        assert!(Objective::resource().select(&evals, &reference).is_none());
    }

    #[test]
    fn poisoned_candidates_never_reach_the_frontier() {
        // regression: NaN metrics used to compare Equal under
        // partial_cmp().unwrap_or(Equal) and could survive into (or
        // scramble the order of) the frontier
        let evals = vec![
            ev("nan-gops", 0.3, f64::NAN),
            ev("nan-score", f64::NAN, 80.0),
            ev("inf-gops", 0.01, f64::INFINITY),
            ev("ok-cheap", 0.2, 10.0),
            ev("ok-fast", 0.9, 90.0),
        ];
        let f = frontier(&evals);
        let labels: Vec<&str> = f.iter().map(|e| e.label.as_str()).collect();
        assert_eq!(labels, vec!["ok-cheap", "ok-fast"], "poisoned candidates survived");
        // poisoned points neither dominate nor are dominated
        assert!(!dominates(&evals[0], &evals[3]));
        assert!(!dominates(&evals[3], &evals[0]));
        assert!(!dominates(&evals[2], &evals[3]), "∞ gops must not dominate everything");
    }

    #[test]
    fn poisoned_candidates_are_infeasible_and_rank_last() {
        let reference = ev("ref", 0.8, 100.0);
        let poisoned = ev("poisoned", f64::NAN, f64::NAN);
        let obj = Objective::resource();
        assert!(!obj.feasible(&poisoned, &reference));
        let healthy = ev("healthy", 0.4, 90.0);
        assert!(obj.rank(&poisoned, &reference) > obj.rank(&healthy, &reference));
        // selection over a poisoned-only pool picks nothing
        assert!(obj.select(&[poisoned], &reference).is_none());
        // and a mixed pool picks the healthy candidate
        let pool = vec![ev("poisoned", f64::NAN, f64::NAN), healthy];
        assert_eq!(obj.select(&pool, &reference).unwrap().label, "healthy");
    }
}
