//! Per-operation / per-module resource cost model.
//!
//! Calibrated against the paper's tables (DESIGN.md §8):
//!
//! * f32 add/sub: 2 DSP (Table 2: V=8 ⇒ 16 DSP = 0.56 % of 2880);
//! * f32 mul: 3 DSP (Table 3: 32 PE × 16 lanes × (3+2) = 2560 ≈ 90 %);
//! * f32 div and min/max: LUT-implemented (div heavy, min/max light);
//! * reader/writer modules: AXI datamover LUT/FF cost growing with the
//!   port width;
//! * CDC plumbing (synchronizer + issuer/packer): LUT+FF only — the
//!   paper observes "a marginal increase in LUT and Register consumption
//!   (less than 1 %)" for vector addition;
//! * BRAM: 18 Kb half-blocks from buffer bytes × port factor.

use super::resources::ResourceVec;
use crate::ir::tasklet::OpCounts;

/// Tunable cost coefficients. Defaults reproduce the paper's tables;
/// ablation benches perturb them.
#[derive(Clone, Debug)]
pub struct CostModel {
    pub dsp_per_fadd: f64,
    pub dsp_per_fmul: f64,
    /// LUTs per f32 divider (no DSP mapping in our calibration).
    pub lut_per_fdiv: f64,
    /// LUTs per f32 min/max (comparator + mux).
    pub lut_per_minmax: f64,
    /// LUT/FF that accompany each DSP-mapped op (alignment logic).
    pub lut_per_flop_op: f64,
    pub reg_per_flop_op: f64,
    /// Base cost of a reader or writer module (AXI state machine).
    pub rw_base_lut: f64,
    pub rw_base_reg: f64,
    /// Extra LUT/FF per byte of port width for readers/writers.
    pub rw_lut_per_byte: f64,
    pub rw_reg_per_byte: f64,
    /// Clock-domain synchronizer (per stream).
    pub sync_lut: f64,
    pub sync_reg: f64,
    /// Issuer/packer (width converter) per byte of the wide side.
    pub conv_lut_per_byte: f64,
    pub conv_reg_per_byte: f64,
    /// FIFO cost per byte of depth×width (LUTRAM below the BRAM
    /// threshold).
    pub fifo_lutmem_per_byte: f64,
    /// Bytes per BRAM 18 Kb half-block.
    pub bram_bytes: f64,
    /// Host/kernel controller per RTL kernel (paper §3.3 file 1).
    pub controller_lut: f64,
    pub controller_reg: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            dsp_per_fadd: 2.0,
            dsp_per_fmul: 3.0,
            lut_per_fdiv: 800.0,
            lut_per_minmax: 64.0,
            lut_per_flop_op: 90.0,
            reg_per_flop_op: 180.0,
            rw_base_lut: 900.0,
            rw_base_reg: 1600.0,
            rw_lut_per_byte: 14.0,
            rw_reg_per_byte: 30.0,
            sync_lut: 110.0,
            sync_reg: 260.0,
            conv_lut_per_byte: 9.0,
            conv_reg_per_byte: 18.0,
            fifo_lutmem_per_byte: 0.6,
            bram_bytes: 2_304.0, // 18 Kb
            controller_lut: 1_200.0,
            controller_reg: 2_200.0,
        }
    }
}

impl CostModel {
    /// Resource cost of one scalar lane of computation.
    pub fn compute_lane(&self, ops: &OpCounts) -> ResourceVec {
        let flop_like = (ops.adds + ops.muls) as f64;
        ResourceVec {
            lut_logic: ops.divs as f64 * self.lut_per_fdiv
                + ops.minmax as f64 * self.lut_per_minmax
                + flop_like * self.lut_per_flop_op,
            lut_memory: 0.0,
            registers: flop_like * self.reg_per_flop_op
                + ops.minmax as f64 * self.lut_per_minmax * 0.5,
            bram: 0.0,
            dsp: ops.adds as f64 * self.dsp_per_fadd + ops.muls as f64 * self.dsp_per_fmul,
        }
    }

    /// A compute pipeline of `lanes` replicated lanes.
    pub fn compute_block(&self, ops: &OpCounts, lanes: usize) -> ResourceVec {
        self.compute_lane(ops).scaled(lanes as f64)
    }

    /// A reader or writer module with the given port width in bytes.
    pub fn reader_writer(&self, port_bytes: usize) -> ResourceVec {
        ResourceVec {
            lut_logic: self.rw_base_lut + self.rw_lut_per_byte * port_bytes as f64,
            lut_memory: 16.0 + 0.25 * port_bytes as f64,
            registers: self.rw_base_reg + self.rw_reg_per_byte * port_bytes as f64,
            bram: 0.0,
            dsp: 0.0,
        }
    }

    /// A clock-domain synchronizer for a stream of `bytes` width.
    pub fn synchronizer(&self, bytes: usize) -> ResourceVec {
        ResourceVec {
            lut_logic: self.sync_lut + 1.5 * bytes as f64,
            lut_memory: 8.0,
            registers: self.sync_reg + 4.0 * bytes as f64,
            bram: 0.0,
            dsp: 0.0,
        }
    }

    /// An issuer or packer converting between `wide_bytes` and
    /// `wide_bytes / factor`.
    pub fn width_converter(&self, wide_bytes: usize, _factor: usize) -> ResourceVec {
        ResourceVec {
            lut_logic: 60.0 + self.conv_lut_per_byte * wide_bytes as f64,
            lut_memory: 4.0,
            registers: 120.0 + self.conv_reg_per_byte * wide_bytes as f64,
            bram: 0.0,
            dsp: 0.0,
        }
    }

    /// A FIFO of `depth` transactions × `bytes` width. Shallow FIFOs go
    /// to LUTRAM; deep ones consume BRAM half-blocks (dual-ported).
    pub fn fifo(&self, depth: usize, bytes: usize) -> ResourceVec {
        let total = (depth * bytes) as f64;
        if total <= 1024.0 {
            ResourceVec {
                lut_logic: 40.0,
                lut_memory: self.fifo_lutmem_per_byte * total,
                registers: 80.0,
                bram: 0.0,
                dsp: 0.0,
            }
        } else {
            ResourceVec {
                lut_logic: 60.0,
                lut_memory: 20.0,
                registers: 110.0,
                bram: (total / self.bram_bytes).ceil().max(1.0),
                dsp: 0.0,
            }
        }
    }

    /// An on-chip buffer of `bytes` with `ports` parallel access ports.
    /// Port replication multiplies block count (the classic BRAM
    /// banking cost that multi-pumping halves: half the internal lanes
    /// ⇒ half the ports ⇒ half the blocks).
    pub fn bram_buffer(&self, bytes: usize, ports: usize) -> ResourceVec {
        let blocks = (bytes as f64 / self.bram_bytes).ceil().max(1.0);
        ResourceVec {
            lut_logic: 25.0 * ports as f64,
            lut_memory: 0.0,
            registers: 45.0 * ports as f64,
            bram: blocks * ports as f64,
            dsp: 0.0,
        }
    }

    /// Host-interface controller per RTL kernel.
    pub fn controller(&self) -> ResourceVec {
        ResourceVec {
            lut_logic: self.controller_lut,
            lut_memory: 60.0,
            registers: self.controller_reg,
            bram: 1.0,
            dsp: 0.0,
        }
    }

    /// Platform infrastructure every design pays once: Vitis shell
    /// glue in the dynamic region, AXI interconnect, DMA engines and
    /// the HBM switch ports. Calibrated so a trivial kernel lands on
    /// the paper's vecadd baseline (~5 % LUT, ~6.8 % BRAM — Table 2).
    pub fn platform_infra(&self) -> ResourceVec {
        ResourceVec {
            lut_logic: 17_500.0,
            lut_memory: 4_200.0,
            registers: 51_000.0,
            bram: 44.0,
            dsp: 0.0,
        }
    }

    /// Per-PE control overhead of a systolic processing element
    /// (forwarding registers, tile counters, drain mux) on top of the
    /// per-lane MAC cost. Calibrated to Table 3's LUT/register columns.
    pub fn systolic_pe_control(&self, lanes: usize) -> ResourceVec {
        ResourceVec {
            lut_logic: 900.0 + 90.0 * lanes as f64,
            lut_memory: 600.0,
            registers: 2_200.0 + 280.0 * lanes as f64,
            bram: 0.0,
            dsp: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mac() -> OpCounts {
        OpCounts { adds: 1, muls: 1, divs: 0, minmax: 0 }
    }

    #[test]
    fn fadd_is_two_dsp_fmul_three() {
        let cm = CostModel::default();
        let add_only = OpCounts { adds: 1, ..Default::default() };
        assert_eq!(cm.compute_lane(&add_only).dsp, 2.0);
        assert_eq!(cm.compute_lane(&mac()).dsp, 5.0);
    }

    #[test]
    fn table2_dsp_calibration() {
        // vecadd at V=8: 8 lanes × 1 add × 2 DSP = 16 → 0.56 % of 2880
        let cm = CostModel::default();
        let add_only = OpCounts { adds: 1, ..Default::default() };
        let block = cm.compute_block(&add_only, 8);
        assert_eq!(block.dsp, 16.0);
        let pct = block.dsp / 2880.0 * 100.0;
        assert!((pct - 0.56).abs() < 0.01, "{pct}");
    }

    #[test]
    fn table3_dsp_calibration() {
        // 32 PEs × 16 lanes × MAC = 2560 DSP → 88.9 % of 2880
        let cm = CostModel::default();
        let block = cm.compute_block(&mac(), 32 * 16);
        let pct = block.dsp / 2880.0 * 100.0;
        assert!((pct - 88.9).abs() < 0.5, "{pct}");
    }

    #[test]
    fn cdc_plumbing_uses_no_dsp_or_bram() {
        let cm = CostModel::default();
        for r in [
            cm.synchronizer(64),
            cm.width_converter(128, 2),
        ] {
            assert_eq!(r.dsp, 0.0);
            assert_eq!(r.bram, 0.0);
            assert!(r.lut_logic > 0.0 && r.registers > 0.0);
        }
    }

    #[test]
    fn fifo_spills_to_bram_when_deep() {
        let cm = CostModel::default();
        assert_eq!(cm.fifo(16, 8).bram, 0.0);
        assert!(cm.fifo(512, 64).bram >= 1.0);
    }

    #[test]
    fn bram_buffer_ports_multiply() {
        let cm = CostModel::default();
        let one = cm.bram_buffer(64 * 1024, 1).bram;
        let two = cm.bram_buffer(64 * 1024, 2).bram;
        assert!((two - 2.0 * one).abs() < 1e-9);
    }

    #[test]
    fn reader_cost_grows_with_width() {
        let cm = CostModel::default();
        assert!(cm.reader_writer(64).lut_logic > cm.reader_writer(4).lut_logic);
    }
}
