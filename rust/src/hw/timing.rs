//! Achievable-frequency model: the stand-in for Vivado place-and-route.
//!
//! The paper's frequencies are outputs of physical P&R; what its
//! conclusions rely on is the *behaviour* of those outputs:
//!
//! 1. small designs close timing near (or above) the shell target;
//! 2. congestion — fabric pressure from high utilization — lowers the
//!    achievable clock, superlinearly past ~60 % (Table 3: 268 MHz at
//!    32 PEs → 252.9 MHz at 64 PEs);
//! 3. a *small* domain (just the compute, after multi-pumping isolates
//!    it from the long data paths) clocks much higher than the full
//!    design — but still degrades as it grows (Table 3 CL1: 452.8 MHz
//!    at 32 PEs → 322.5 MHz at 64);
//! 4. Vivado refuses requests above 650 MHz, yet can deliver slightly
//!    more than requested (Table 6: 674.7 MHz);
//! 5. DSP silicon caps everything at 891 MHz;
//! 6. SLR crossings hurt badly (§4.2: 25 % scaling efficiency).
//!
//! The model here reproduces exactly those six behaviours, with a
//! deterministic seeded jitter standing in for P&R's run-to-run
//! scatter. The *effective clock rate* of a double-pumped design is
//! `min(CL0, CL1/M)` (paper §2.1), computed by [`effective_clock`].

use super::resources::Utilization;
use crate::util::Rng;

/// The achievable clock for one clock domain.
#[derive(Clone, Copy, Debug)]
pub struct ClockReport {
    /// Frequency Vivado would declare after P&R, in MHz.
    pub achieved_mhz: f64,
    /// The frequency that was requested.
    pub requested_mhz: f64,
    /// Fabric congestion score in [0, ∞) that produced it.
    pub congestion: f64,
}

/// Model parameters. Defaults calibrated to Tables 2–6.
#[derive(Clone, Debug)]
pub struct TimingModel {
    /// Intrinsic fabric limit for trivial logic (MHz): what an almost
    /// empty pipelined design can close at.
    pub fabric_fmax_mhz: f64,
    /// Congestion scale: achieved = base / (1 + alpha * congestion).
    pub alpha: f64,
    /// Utilization knee past which congestion grows superlinearly.
    pub knee: f64,
    /// Superlinear exponent past the knee.
    pub gamma: f64,
    /// Long-path penalty for designs spanning memory interfaces (the
    /// slow domain always carries the HBM/PCIe paths).
    pub io_span_penalty: f64,
    /// Relative sigma of the deterministic P&R jitter.
    pub jitter: f64,
    /// DSP silicon cap (MHz).
    pub dsp_fmax_mhz: f64,
    /// Maximum requestable clock (MHz).
    pub max_requested_mhz: f64,
}

impl Default for TimingModel {
    fn default() -> Self {
        TimingModel {
            fabric_fmax_mhz: 742.0,
            alpha: 1.05,
            knee: 0.60,
            gamma: 2.2,
            io_span_penalty: 0.35,
            jitter: 0.013,
            dsp_fmax_mhz: 891.0,
            max_requested_mhz: 650.0,
        }
    }
}

/// What a domain contains, for timing purposes.
#[derive(Clone, Copy, Debug)]
pub struct DomainProfile {
    /// Utilization of the SLR by *this domain's* logic.
    pub util: Utilization,
    /// Utilization of the SLR by the *whole design* (routing is shared;
    /// a small fast domain inside a packed chip still suffers).
    pub design_util: Utilization,
    /// Does the domain include off-chip interfaces (readers/writers)?
    pub touches_io: bool,
    /// Number of SLR crossings on the domain's paths.
    pub slr_crossings: usize,
}

impl TimingModel {
    /// Congestion score of a domain.
    pub fn congestion(&self, p: &DomainProfile) -> f64 {
        // own fabric pressure + a share of the surrounding design's
        let own = 0.6 * p.util.fabric_pressure();
        let ambient = 0.25 * p.design_util.fabric_pressure();
        let mut c = own + ambient;
        let knee_excess = (p.design_util.max_fraction() - self.knee).max(0.0);
        c += knee_excess.powf(self.gamma) * 3.0;
        if p.touches_io {
            c += self.io_span_penalty;
        }
        c += p.slr_crossings as f64 * 0.75;
        c
    }

    /// Compute-density congestion: dense DSP columns and banked BRAM
    /// route poorly *at high clock targets* (the fast domain of a big
    /// systolic array closes far below the fabric limit — Table 3's
    /// CL1 drop from 452.8 to 322.5 MHz as PEs grow), but barely affect
    /// low-frequency domains. Scales with the requested clock.
    fn density_penalty(&self, p: &DomainProfile, requested_mhz: f64) -> f64 {
        let density = 0.3 * p.util.dsp + 0.15 * p.util.bram;
        density * (requested_mhz / self.max_requested_mhz).min(1.2)
    }

    /// Achieved frequency for a domain given a requested clock, with
    /// deterministic jitter drawn from `rng`.
    pub fn achieve(&self, requested_mhz: f64, p: &DomainProfile, rng: &mut Rng) -> ClockReport {
        let requested = requested_mhz.min(self.max_requested_mhz);
        let congestion = self.congestion(p) + self.density_penalty(p, requested);
        let base = self.fabric_fmax_mhz / (1.0 + self.alpha * congestion);
        // P&R aims for the request; it can exceed it a little when the
        // fabric allows (Table 6: 674.7 achieved for a 650 request), and
        // falls short when congested.
        let headroom = base.min(requested * 1.06);
        let jittered = headroom * (1.0 + self.jitter * rng.gauss());
        let achieved = jittered.min(requested * 1.055).min(self.dsp_fmax_mhz);
        ClockReport { achieved_mhz: achieved, requested_mhz: requested, congestion }
    }
}

/// Effective clock rate of a multi-pumped design (paper §2.1): the
/// minimum of the slow-domain clock and `1/M` of the fast-domain clock.
pub fn effective_clock(cl0_mhz: f64, cl1_mhz: Option<f64>, factor: usize) -> f64 {
    match cl1_mhz {
        Some(cl1) => cl0_mhz.min(cl1 / factor as f64),
        None => cl0_mhz,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::resources::Utilization;

    fn util(frac: f64) -> Utilization {
        Utilization {
            lut_logic: frac,
            lut_memory: frac * 0.4,
            registers: frac,
            bram: frac,
            dsp: frac,
        }
    }

    fn profile(frac: f64, io: bool) -> DomainProfile {
        DomainProfile { util: util(frac), design_util: util(frac), touches_io: io, slr_crossings: 0 }
    }

    #[test]
    fn small_design_meets_shell_clock() {
        let tm = TimingModel::default();
        let mut rng = Rng::new(1);
        let r = tm.achieve(300.0, &profile(0.06, true), &mut rng);
        assert!(r.achieved_mhz > 290.0, "{}", r.achieved_mhz);
        assert!(r.achieved_mhz < 340.0, "{}", r.achieved_mhz);
    }

    #[test]
    fn congestion_lowers_clock() {
        let tm = TimingModel::default();
        let mut rng = Rng::new(2);
        let lo = tm.achieve(650.0, &profile(0.1, false), &mut rng).achieved_mhz;
        let hi = tm.achieve(650.0, &profile(0.9, false), &mut rng).achieved_mhz;
        assert!(hi < lo * 0.7, "hi={hi} lo={lo}");
    }

    #[test]
    fn isolated_compute_domain_clocks_higher_than_io_domain() {
        // behaviour 3: the multi-pumped domain (no IO span) beats the
        // slow domain at the same utilization
        let tm = TimingModel::default();
        let mut rng = Rng::new(3);
        let fast = tm.achieve(650.0, &profile(0.3, false), &mut rng).achieved_mhz;
        let slow = tm.achieve(650.0, &profile(0.3, true), &mut rng).achieved_mhz;
        assert!(fast > slow * 1.2, "fast={fast} slow={slow}");
    }

    #[test]
    fn achieved_can_slightly_exceed_request() {
        let tm = TimingModel::default();
        // near-empty fabric, many seeds: some runs exceed 650
        let mut any_above = false;
        for seed in 0..32 {
            let mut rng = Rng::new(seed);
            let r = tm.achieve(650.0, &profile(0.02, false), &mut rng);
            assert!(r.achieved_mhz <= 891.0);
            if r.achieved_mhz > 650.0 {
                any_above = true;
            }
        }
        assert!(any_above, "expected some runs above the 650 request (Table 6 behaviour)");
    }

    #[test]
    fn dsp_cap_enforced() {
        let mut tm = TimingModel::default();
        tm.fabric_fmax_mhz = 5000.0;
        tm.max_requested_mhz = 5000.0;
        let mut rng = Rng::new(5);
        let r = tm.achieve(4000.0, &profile(0.01, false), &mut rng);
        assert!(r.achieved_mhz <= 891.0);
    }

    #[test]
    fn slr_crossing_penalty() {
        let tm = TimingModel::default();
        let mut rng = Rng::new(6);
        let mut p = profile(0.4, true);
        let single = tm.achieve(300.0, &p, &mut rng).achieved_mhz;
        p.slr_crossings = 2;
        let multi = tm.achieve(300.0, &p, &mut rng).achieved_mhz;
        assert!(multi < single * 0.75, "multi={multi} single={single}");
    }

    #[test]
    fn effective_clock_rule() {
        assert_eq!(effective_clock(300.0, None, 1), 300.0);
        // CL1/2 < CL0 → limited by fast domain
        assert_eq!(effective_clock(300.0, Some(500.0), 2), 250.0);
        // CL1/2 > CL0 → limited by slow domain
        assert_eq!(effective_clock(300.0, Some(680.0), 2), 300.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let tm = TimingModel::default();
        let a = tm.achieve(650.0, &profile(0.5, true), &mut Rng::new(42)).achieved_mhz;
        let b = tm.achieve(650.0, &profile(0.5, true), &mut Rng::new(42)).achieved_mhz;
        assert_eq!(a, b);
    }
}
