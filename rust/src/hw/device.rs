//! Device descriptions: the Xilinx Alveo U280 of the evaluation.

use super::resources::ResourceVec;

/// An HBM pseudo-channel. The U280 exposes 32 banks, all wired to SLR0
/// (paper §4); each bank stores exactly one container in the paper's
/// configuration so bank conflicts are avoided.
#[derive(Clone, Debug)]
pub struct HbmBank {
    pub index: usize,
    /// Per-bank capacity in bytes (U280: 8 GiB / 32 banks = 256 MiB).
    pub capacity: usize,
    /// Peak per-bank bandwidth in bytes/cycle at the shell clock for a
    /// 256-bit AXI port (32 B/cycle).
    pub bytes_per_cycle: usize,
}

/// A Super Logic Region (die) with its resource pool.
#[derive(Clone, Debug)]
pub struct Slr {
    pub index: usize,
    pub pool: ResourceVec,
    /// Whether HBM is directly attached (SLR0 only on the U280).
    pub hbm_attached: bool,
}

/// The accelerator card model.
#[derive(Clone, Debug)]
pub struct Device {
    pub name: String,
    pub slrs: Vec<Slr>,
    pub hbm_banks: Vec<HbmBank>,
    /// Shell (slow-domain) clock target in MHz the toolchain aims for.
    pub shell_clock_mhz: f64,
    /// Maximum clock Vivado accepts as a request (§4: 650 MHz for the
    /// evaluated version).
    pub max_requested_mhz: f64,
    /// DSP48 silicon limit (U280 datasheet: 891 MHz).
    pub dsp_fmax_mhz: f64,
    /// Frequency penalty factor per SLR crossing (die-to-die paths).
    pub slr_crossing_penalty: f64,
}

impl Device {
    /// The Xilinx Alveo U280 with the paper's Table-1 per-SLR pools.
    pub fn u280() -> Device {
        // Table 1: LUT Logic 439 K, LUT Memory 205 K, Registers 879 K,
        // BRAM 672, DSPs 2880 — per SLR (SLR0 shown; we use it for all
        // three, which matches the U280 floorplan closely enough for
        // replication experiments).
        let pool = ResourceVec::new(439_000.0, 205_000.0, 879_000.0, 672.0, 2_880.0);
        Device {
            name: "xilinx_u280_xdma_201920_3".to_string(),
            slrs: (0..3)
                .map(|index| Slr { index, pool, hbm_attached: index == 0 })
                .collect(),
            hbm_banks: (0..32)
                .map(|index| HbmBank {
                    index,
                    capacity: 256 * 1024 * 1024,
                    bytes_per_cycle: 32,
                })
                .collect(),
            shell_clock_mhz: 300.0,
            max_requested_mhz: 650.0,
            dsp_fmax_mhz: 891.0,
            slr_crossing_penalty: 0.35,
        }
    }

    pub fn slr(&self, i: usize) -> &Slr {
        &self.slrs[i]
    }

    /// Single-SLR pool (the evaluation's default configuration).
    pub fn slr0_pool(&self) -> ResourceVec {
        self.slrs[0].pool
    }

    /// Bank by index; panics on overflow (the coordinator checks the
    /// container count beforehand).
    pub fn bank(&self, i: usize) -> &HbmBank {
        assert!(
            i < self.hbm_banks.len(),
            "device {} has {} HBM banks, bank {i} requested",
            self.name,
            self.hbm_banks.len()
        );
        &self.hbm_banks[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u280_matches_table1() {
        let d = Device::u280();
        let p = d.slr0_pool();
        assert_eq!(p.lut_logic, 439_000.0);
        assert_eq!(p.lut_memory, 205_000.0);
        assert_eq!(p.registers, 879_000.0);
        assert_eq!(p.bram, 672.0);
        assert_eq!(p.dsp, 2_880.0);
        assert_eq!(d.slrs.len(), 3);
        assert_eq!(d.hbm_banks.len(), 32);
        assert!(d.slrs[0].hbm_attached);
        assert!(!d.slrs[1].hbm_attached);
    }

    #[test]
    fn clock_limits() {
        let d = Device::u280();
        assert_eq!(d.max_requested_mhz, 650.0);
        assert_eq!(d.dsp_fmax_mhz, 891.0);
        assert!(d.shell_clock_mhz < d.max_requested_mhz);
    }

    #[test]
    #[should_panic(expected = "32 HBM banks")]
    fn bank_overflow_panics() {
        Device::u280().bank(32);
    }
}
