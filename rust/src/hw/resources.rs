//! Resource vectors and pool accounting.
//!
//! The five resource classes the paper reports (Tables 1–6): LUTs used
//! as logic, LUTs used as memory (distributed RAM / shift registers),
//! flip-flop registers, BRAM (18 Kb half-blocks counted as the tables
//! do), and DSP48 slices.

use std::ops::{Add, AddAssign, Mul};

/// A count of each resource class.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ResourceVec {
    pub lut_logic: f64,
    pub lut_memory: f64,
    pub registers: f64,
    pub bram: f64,
    pub dsp: f64,
}

impl ResourceVec {
    pub const ZERO: ResourceVec =
        ResourceVec { lut_logic: 0.0, lut_memory: 0.0, registers: 0.0, bram: 0.0, dsp: 0.0 };

    pub fn new(lut_logic: f64, lut_memory: f64, registers: f64, bram: f64, dsp: f64) -> Self {
        ResourceVec { lut_logic, lut_memory, registers, bram, dsp }
    }

    /// Element-wise utilization fraction against a pool.
    pub fn utilization(&self, pool: &ResourceVec) -> Utilization {
        Utilization {
            lut_logic: self.lut_logic / pool.lut_logic,
            lut_memory: self.lut_memory / pool.lut_memory,
            registers: self.registers / pool.registers,
            bram: self.bram / pool.bram,
            dsp: self.dsp / pool.dsp,
        }
    }

    /// Does the vector fit in the pool?
    pub fn fits(&self, pool: &ResourceVec) -> bool {
        self.lut_logic <= pool.lut_logic
            && self.lut_memory <= pool.lut_memory
            && self.registers <= pool.registers
            && self.bram <= pool.bram
            && self.dsp <= pool.dsp
    }

    pub fn scaled(&self, k: f64) -> ResourceVec {
        ResourceVec {
            lut_logic: self.lut_logic * k,
            lut_memory: self.lut_memory * k,
            registers: self.registers * k,
            bram: self.bram * k,
            dsp: self.dsp * k,
        }
    }
}

impl Add for ResourceVec {
    type Output = ResourceVec;
    fn add(self, o: ResourceVec) -> ResourceVec {
        ResourceVec {
            lut_logic: self.lut_logic + o.lut_logic,
            lut_memory: self.lut_memory + o.lut_memory,
            registers: self.registers + o.registers,
            bram: self.bram + o.bram,
            dsp: self.dsp + o.dsp,
        }
    }
}

impl AddAssign for ResourceVec {
    fn add_assign(&mut self, o: ResourceVec) {
        *self = *self + o;
    }
}

impl Mul<f64> for ResourceVec {
    type Output = ResourceVec;
    fn mul(self, k: f64) -> ResourceVec {
        self.scaled(k)
    }
}

/// Utilization fractions (0..1 per class) — rendered as percentages in
/// the tables.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Utilization {
    pub lut_logic: f64,
    pub lut_memory: f64,
    pub registers: f64,
    pub bram: f64,
    pub dsp: f64,
}

impl Utilization {
    /// The constraining (maximum) utilization across classes.
    pub fn max_fraction(&self) -> f64 {
        self.lut_logic
            .max(self.lut_memory)
            .max(self.registers)
            .max(self.bram)
            .max(self.dsp)
    }

    /// Weighted mean utilization: routing pressure correlates with how
    /// much of the *fabric* (LUTs + registers) is occupied; BRAM/DSP
    /// columns matter less for congestion.
    pub fn fabric_pressure(&self) -> f64 {
        0.40 * self.lut_logic + 0.15 * self.lut_memory + 0.30 * self.registers
            + 0.075 * self.bram
            + 0.075 * self.dsp
    }

    pub fn percentages(&self) -> [f64; 5] {
        [
            self.lut_logic * 100.0,
            self.lut_memory * 100.0,
            self.registers * 100.0,
            self.bram * 100.0,
            self.dsp * 100.0,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let a = ResourceVec::new(1.0, 2.0, 3.0, 4.0, 5.0);
        let b = a + a;
        assert_eq!(b.dsp, 10.0);
        assert_eq!((a * 2.0).lut_logic, 2.0);
        let mut c = a;
        c += a;
        assert_eq!(c, b);
    }

    #[test]
    fn utilization_and_fit() {
        let pool = ResourceVec::new(100.0, 100.0, 100.0, 100.0, 100.0);
        let used = ResourceVec::new(50.0, 10.0, 25.0, 99.0, 101.0);
        let u = used.utilization(&pool);
        assert!((u.dsp - 1.01).abs() < 1e-12);
        assert!((u.max_fraction() - 1.01).abs() < 1e-12);
        assert!(!used.fits(&pool));
        assert!(ResourceVec::new(1.0, 1.0, 1.0, 1.0, 1.0).fits(&pool));
    }

    #[test]
    fn fabric_pressure_weights_sum_to_one() {
        let all = Utilization {
            lut_logic: 1.0,
            lut_memory: 1.0,
            registers: 1.0,
            bram: 1.0,
            dsp: 1.0,
        };
        assert!((all.fabric_pressure() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentages_scale() {
        let u = Utilization { dsp: 0.5, ..Default::default() };
        assert_eq!(u.percentages()[4], 50.0);
    }
}
