//! Hardware substrate model.
//!
//! The paper evaluates on a physical Xilinx Alveo U280 through Vitis
//! 2020.2; neither exists in this environment, so this module models the
//! parts of that stack the evaluation actually observes (DESIGN.md §2):
//!
//! * [`device`] — the U280: per-SLR resource pools (paper Table 1),
//!   HBM banks, shell clocking limits;
//! * [`resources`] — resource vectors (LUT logic/memory, registers,
//!   BRAM, DSP) with pool accounting and utilization percentages;
//! * [`cost`] — per-operation and per-module resource costs calibrated
//!   against the paper's tables (f32 add = 2 DSP, mul = 3 DSP, CDC
//!   plumbing in LUTs+registers, BRAM from buffer footprints);
//! * [`timing`] — the achievable-frequency model standing in for
//!   place-and-route: congestion as a function of utilization and
//!   domain span, the 650 MHz Vivado request cap, the 891 MHz DSP
//!   silicon cap, deterministic seeded "P&R noise", and the paper's
//!   *effective clock rate* `min(CL0, CL1/M)`.

pub mod cost;
pub mod device;
pub mod resources;
pub mod timing;

pub use device::{Device, HbmBank};
pub use resources::{ResourceVec, Utilization};
pub use timing::{ClockReport, TimingModel};
