//! Scenario: StencilFlow-style chained stencils (paper §4.3).
//!
//! Builds Jacobi-3D chains of growing depth, shows how double-pumping
//! halves the per-stage DSP/BRAM cost (letting deeper chains fit), and
//! verifies a 4-stage chain functionally against the PJRT golden model.
//!
//! Run with: `cargo run --release --example stencil_chain`

use temporal_vec::apps::stencil;
use temporal_vec::coordinator::{compile, BuildSpec};
use temporal_vec::hw::Device;
use temporal_vec::ir::{PumpMode, StencilKind};
use temporal_vec::runtime::{artifact, GoldenRunner};
use temporal_vec::sim::{run_functional, Hbm};
use temporal_vec::util::table::{pct, Table};
use temporal_vec::util::Rng;

fn main() -> Result<(), String> {
    let kind = StencilKind::Jacobi3D;
    let w = stencil::paper_vec_width(kind);
    let (nx, ny, nz) = (stencil::PAPER_NX, stencil::PAPER_NY, stencil::PAPER_NZ);
    let pool = Device::u280().slr0_pool();

    let mut t = Table::new(
        "Jacobi-3D chain depth sweep (8-way vectorized)",
        &["S", "variant", "DSP%", "BRAM%", "fits SLR"],
    );
    for &s in &[8usize, 16, 24, 40, 56] {
        for pump in [false, true] {
            let mut spec = BuildSpec::new(stencil::build(kind, s, w))
                .bind("NX", nx)
                .bind("NY", ny)
                .bind("NZ", nz)
                .bind("NZ_v", nz / w as i64)
                .cl0(315.0);
            if pump {
                spec = spec.pumped(2, PumpMode::Resource);
            }
            let c = compile(spec)?;
            let fits = c.report.resources.fits(&pool);
            t.row(vec![
                s.to_string(),
                if pump { "DP" } else { "O" }.into(),
                pct(c.report.util_percent()[4]),
                pct(c.report.util_percent()[3]),
                if fits { "yes" } else { "NO" }.into(),
            ]);
        }
    }
    t.footnote("DP halves the per-stage cost: chains ~2x deeper fit the SLR");
    println!("{}", t.render());

    // functional check: 4-stage chain at 32^3 against the AOT artifact
    println!("functional check (32x32x32, S=4, double-pumped) vs PJRT golden...");
    let gx = stencil::GOLDEN_NX;
    let c = compile(
        BuildSpec::new(stencil::build(kind, stencil::GOLDEN_STAGES, w))
            .pumped(2, PumpMode::Resource)
            .bind("NX", gx)
            .bind("NY", 32)
            .bind("NZ", 32)
            .bind("NZ_v", 32 / w as i64),
    )?;
    let mut rng = Rng::new(11);
    let v = rng.f32_vec((gx * 32 * 32) as usize);
    let mut hbm = Hbm::new();
    hbm.load("v_in", v.clone());
    let out = run_functional(&c.design, hbm)?;
    let got = out.hbm.read("v_out");
    let mut runner = GoldenRunner::new(&artifact::artifacts_dir())?;
    let want = runner.run("jacobi3d", &[&v])?;
    let worst = got
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w).abs())
        .fold(0.0f32, f32::max);
    println!("max abs err vs golden: {worst:.2e}");
    assert!(worst < 1e-4);
    println!("stencil_chain OK");
    Ok(())
}
