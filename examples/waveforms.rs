//! Reproduce the paper's **Figure 2**: waveforms of the original and
//! double-pumped vector addition (M=2, V=2).
//!
//! The exact cycle-stepped simulator traces per-module activity; the
//! rendering shows the slow-clock ruler on top ( | marks a clk0 edge)
//! and one row per module. In the double-pumped design the issuers,
//! compute and packers tick on clk1 (twice per ruler mark) while the
//! readers/writers and synchronizers stay on clk0 — exactly the
//! waveform structure of Figure 2 (2) and (3).
//!
//! Run with: `cargo run --release --example waveforms`

use temporal_vec::coordinator::{compile, BuildSpec};
use temporal_vec::ir::PumpMode;
use temporal_vec::sim::{run_traced, Hbm};
use temporal_vec::util::Rng;

fn trace(pump: bool) -> Result<(), String> {
    let n = 24i64;
    let mut spec = BuildSpec::new(temporal_vec::apps::vecadd::build())
        .vectorized("vadd", 2)
        .bind("N", n);
    if pump {
        spec = spec.pumped(2, PumpMode::Resource);
    }
    let c = compile(spec)?;
    let mut rng = Rng::new(2);
    let mut hbm = Hbm::new();
    hbm.load("x", rng.f32_vec(n as usize));
    hbm.load("y", rng.f32_vec(n as usize));
    let t = run_traced(&c.design, hbm, 96)?;
    println!(
        "{} vector addition (V=2{}):\n{}",
        if pump { "(2)+(3) double-pumped" } else { "(1) original" },
        if pump { ", M=2" } else { "" },
        t.render()
    );
    Ok(())
}

fn main() -> Result<(), String> {
    println!("Figure 2 reproduction — waveforms from the exact simulator\n");
    trace(false)?;
    trace(true)?;
    println!("note how the compute row fires twice per clk0 edge in the pumped design,");
    println!("while readers/writers keep the slow cadence — temporal vectorization.");
    Ok(())
}
