//! Scenario: temporal vectorization of a dependent computation
//! (paper §4.4).
//!
//! Floyd–Warshall cannot be traditionally vectorized — each `k`
//! iteration depends on the previous one. Multi-pumping in *throughput*
//! mode leaves the computation untouched and feeds it two elements per
//! slow cycle; the relaxation datapath runs in the fast domain.
//!
//! Shows the transformation's feasibility reasoning, the O vs DP cycle
//! model at paper scale (500 nodes), and verifies shortest paths at
//! artifact scale (64 nodes) against the PJRT golden model.
//!
//! Run with: `cargo run --release --example floyd_warshall`

use temporal_vec::analysis::{check_temporal, check_traditional, scope_movement};
use temporal_vec::apps::floyd_warshall as fw;
use temporal_vec::coordinator::{compile, BuildSpec};
use temporal_vec::ir::PumpMode;
use temporal_vec::runtime::{artifact, GoldenRunner};
use temporal_vec::sim::{rate_model, run_functional, Hbm};
use temporal_vec::symbolic::SymbolTable;

fn main() -> Result<(), String> {
    // --- the feasibility story: why FW is temporally but not
    // --- traditionally vectorizable (illustrated on a scan, the
    // --- minimal dependent loop the DSL can express)
    let scan = temporal_vec::frontend::compile(
        "
program scan(N):
  x: f32[N] @ hbm
  for i in 1:N:
    x[i] = x[i] + x[i-1]
",
    )?;
    let entry = scan.find_map_entry("map0").unwrap();
    let mv = scope_movement(&scan, entry)?;
    let trad = check_traditional(&scan, &mv, 1, &SymbolTable::new().with("N", 64));
    let temp = check_temporal(&scan, &mv, 1);
    println!("dependent loop, traditional vectorization: {trad:?}");
    println!("dependent loop, temporal vectorization:    {temp:?}\n");
    assert!(!trad.is_ok() && temp.is_ok());

    // --- paper-scale cycle model (Table 6)
    let n = fw::PAPER_N;
    for pump in [false, true] {
        let mut spec = BuildSpec::new(fw::build()).bind("N", n).cl0(fw::CL0_REQUEST_MHZ);
        if pump {
            spec = spec.pumped(2, PumpMode::Throughput);
        }
        let c = compile(spec)?;
        let stats = rate_model(&c.design);
        println!(
            "{}: CL0 {:.1}{} -> effective {:.1} MHz, {} slow cycles, {:.2} s",
            if pump { "DP" } else { "O " },
            c.report.cl0.achieved_mhz,
            c.report
                .cl1
                .map(|r| format!(" / CL1 {:.1}", r.achieved_mhz))
                .unwrap_or_default(),
            c.report.effective_mhz,
            stats.slow_cycles,
            stats.seconds_at(c.report.effective_mhz),
        );
    }

    // --- functional verification at artifact scale
    println!("\nfunctional check (64 nodes, throughput-pumped) vs PJRT golden...");
    let gn = fw::GOLDEN_N;
    let c = compile(
        BuildSpec::new(fw::build())
            .pumped(2, PumpMode::Throughput)
            .bind("N", gn),
    )?;
    let d = fw::random_graph(gn as usize, 99, 0.25);
    let mut hbm = Hbm::new();
    hbm.load("dist", d.clone());
    let out = run_functional(&c.design, hbm)?;
    let got = out.hbm.read("dist");
    let mut runner = GoldenRunner::new(&artifact::artifacts_dir())?;
    let want = runner.run("floyd_warshall", &[&d])?;
    let worst = got
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w).abs() / w.abs().max(1.0))
        .fold(0.0f32, f32::max);
    println!("max rel err vs golden: {worst:.2e}");
    assert!(worst < 1e-5);
    println!("floyd_warshall OK");
    Ok(())
}
