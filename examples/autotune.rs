//! Autotuning walkthrough: the `dse` subsystem picking the paper's
//! configurations automatically.
//!
//! 1. **vecadd** — search Table 2's grid (V ∈ {2,4,8} × pumping) with
//!    the *resource* objective: the search lands on V=8 double-pumped,
//!    the paper's headline half-the-DSPs-for-free configuration;
//! 2. **matmul** — sweep the PE counts of Table 3 and the full pump
//!    grid; print the resource-vs-throughput Pareto frontier and the
//!    selected design;
//! 3. **strategies** — exhaustive, greedy hill-climbing, seeded
//!    simulated annealing and successive halving on the same space,
//!    sharing one memoized evaluator: later searches are mostly cache
//!    hits (incremental sweeps);
//! 4. **persistence** — the same evaluator cache flushed to disk and
//!    reloaded by a "second process": the reload re-runs the full
//!    sweep with zero new compiles, the `--cache-dir` story.
//!
//! Run with: `cargo run --release --example autotune`

use temporal_vec::apps;
use temporal_vec::coordinator::BuildSpec;
use temporal_vec::dse::{
    run_search, Evaluator, Objective, SearchBase, SearchConfig, SpaceOptions, Strategy,
};
use temporal_vec::hw::Device;
use temporal_vec::util::table::{fnum, pct, Table};

fn frontier_table(outcome: &temporal_vec::dse::SearchOutcome) -> String {
    let mut t = Table::new(
        format!(
            "Pareto frontier ({} non-dominated design points)",
            outcome.frontier.len()
        ),
        &["config", "DSPs", "DSP%", "BRAM%", "eff MHz", "GOp/s", "score"],
    );
    for e in &outcome.frontier {
        let u = e.report.util_percent();
        t.row(vec![
            e.label.clone(),
            fnum(e.total_resources.dsp, 0),
            pct(u[4]),
            pct(u[3]),
            fnum(e.report.effective_mhz, 1),
            fnum(e.gops, 1),
            fnum(e.resource_score, 3),
        ]);
    }
    t.render()
}

fn main() -> Result<(), String> {
    let device = Device::u280();
    let seed = 1u64;

    println!("=== 1. vecadd: Table 2's grid, resource objective ===");
    let n = 1i64 << 22;
    let vecadd_bases = [SearchBase {
        spec: BuildSpec::new(apps::vecadd::build()).bind("N", n).seeded(seed),
        flops: apps::vecadd::flops(n),
    }];
    let vecadd_opts = SpaceOptions {
        vector_widths: vec![2, 4, 8],
        pump_factors: vec![2, 4],
        pump_modes: vec![temporal_vec::ir::PumpMode::Resource],
        max_replicas: 1,
        cl0_requests_mhz: vec![],
        mixed_factors: false,
    };
    let ev = Evaluator::new();
    let out = run_search(
        &ev,
        &vecadd_bases,
        &device,
        &vecadd_opts,
        &SearchConfig::exhaustive(Objective::resource()),
    )?;
    println!("{}", frontier_table(&out));
    let reference = out.reference.as_ref().unwrap();
    let chosen = out.chosen.as_ref().unwrap();
    println!(
        "paper Table 2 best DP config: V=8 DP — search chose: {} \
         ({:.0}% of unpumped DSPs, {:.0}% of unpumped throughput)\n",
        chosen.label,
        chosen.total_resources.dsp / reference.total_resources.dsp * 100.0,
        chosen.gops / reference.gops * 100.0
    );

    println!("=== 2. matmul: PE sweep x pump grid, both objectives ===");
    let nmk = 1024i64;
    let mm_bases: Vec<SearchBase> = [16usize, 32, 64]
        .iter()
        .map(|&pes| {
            let mut spec = BuildSpec::new(apps::matmul::build(pes)).cl0(270.0).seeded(seed);
            for (s, v) in apps::matmul::bindings(nmk) {
                spec = spec.bind(&s, v);
            }
            SearchBase { spec, flops: apps::matmul::flops(nmk, nmk, nmk) }
        })
        .collect();
    let mm_opts = SpaceOptions::for_device(&device);
    let mm_ev = Evaluator::new();
    for objective in [Objective::resource(), Objective::throughput()] {
        let out = run_search(
            &mm_ev,
            &mm_bases,
            &device,
            &mm_opts,
            &SearchConfig::exhaustive(objective),
        )?;
        println!("objective: {}", objective.name());
        println!("{}", frontier_table(&out));
        let reference = out.reference.as_ref().unwrap();
        if let Some(chosen) = &out.chosen {
            println!(
                "chosen: {} — {:.0} DSPs ({:.0}% of unpumped), {:.1} GOp/s \
                 ({:.0}% of unpumped)\n",
                chosen.label,
                chosen.total_resources.dsp,
                chosen.total_resources.dsp / reference.total_resources.dsp * 100.0,
                chosen.gops,
                chosen.gops / reference.gops * 100.0
            );
        }
    }
    println!(
        "shared evaluator across the two objectives: {} compiles, {} cache hits",
        mm_ev.cache_misses(),
        mm_ev.cache_hits()
    );

    println!("\n=== 3. four strategies on the same space ===");
    let shared = Evaluator::new();
    for strategy in [
        Strategy::Exhaustive,
        Strategy::Greedy,
        Strategy::Anneal,
        Strategy::Halving,
    ] {
        let cfg = SearchConfig {
            strategy,
            objective: Objective::resource(),
            budget: None,
            seed: 17,
            deadline_ms: None,
            sim_cycle_budget: None,
        };
        let before = shared.cache_misses();
        let out = run_search(&shared, &mm_bases, &device, &mm_opts, &cfg)?;
        let chosen = out.chosen.as_ref().unwrap();
        println!(
            "{:<11} evaluations issued: {:>3} (new compiles: {:>3})  chosen: {}",
            strategy.name(),
            out.evaluated,
            shared.cache_misses() - before,
            chosen.label
        );
    }
    println!("later strategies after exhaustive are mostly cache: incremental re-tuning works");

    println!("\n=== 4. persistent cache across processes ===");
    let cache_dir = std::env::temp_dir().join(format!("tvec-autotune-{}", std::process::id()));
    std::fs::create_dir_all(&cache_dir).map_err(|e| e.to_string())?;
    let cfg = SearchConfig::exhaustive(Objective::resource());
    let first = Evaluator::with_cache_dir(&cache_dir);
    run_search(&first, &mm_bases, &device, &mm_opts, &cfg)?;
    let flushed = first.flush()?;
    println!(
        "process 1: {} compiles, flushed {flushed} entries to {}",
        first.cache_misses(),
        cache_dir.display()
    );
    let second = Evaluator::with_cache_dir(&cache_dir);
    run_search(&second, &mm_bases, &device, &mm_opts, &cfg)?;
    println!(
        "process 2: loaded {} entries, re-ran the sweep with {} new compiles \
         ({} cache hits)",
        second.loaded_entries(),
        second.cache_misses(),
        second.cache_hits()
    );
    assert_eq!(second.cache_misses(), 0, "warm re-run must not compile anything");
    let _ = std::fs::remove_dir_all(&cache_dir);

    println!("\n=== 5. mixed per-region pump factors on the stencil chain ===");
    // paper §3.4 pumps the largest streamable subgraph as a whole; the
    // mixed dimension assigns one factor per region instead. On the
    // 16-stage jacobi chain a 4/2 split undercuts the best uniform
    // point on the resource axis: the small factor-4 block closes
    // timing at the 650 MHz request cap while half the chain runs at
    // quarter width.
    let (st_bases, mut st_opts) =
        temporal_vec::coordinator::search_problem("stencil", Some(1 << 10), seed, &device)?;
    st_opts.mixed_factors = true;
    st_opts.pump_modes = vec![temporal_vec::ir::PumpMode::Resource];
    st_opts.max_replicas = 1;
    let regions = temporal_vec::analysis::partition_streamable(st_bases[0].spec.sdfg());
    println!("stencil chain: {} streamable regions", regions.len());
    let st_out = run_search(
        &Evaluator::new(),
        &st_bases,
        &device,
        &st_opts,
        &SearchConfig::exhaustive(Objective::resource()),
    )?;
    println!("{}", frontier_table(&st_out));
    let st_ref = st_out.reference.as_ref().unwrap();
    let uniform: Vec<_> = st_out
        .evaluations
        .iter()
        .filter(|e| e.point.regions.is_none())
        .cloned()
        .collect();
    if let Some(best_uniform) = Objective::resource().select(&uniform, st_ref) {
        let best_mixed_score = st_out
            .frontier
            .iter()
            .filter(|e| e.point.regions.is_some())
            .map(|e| e.resource_score)
            .fold(f64::INFINITY, f64::min);
        println!(
            "best uniform point: {} (score {:.3}); cheapest mixed frontier point scores {:.3}",
            best_uniform.label, best_uniform.resource_score, best_mixed_score
        );
    }
    Ok(())
}
