//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. **Pumping factor M ∈ {2, 3, 4}** — the paper evaluates M=2 only
//!    ("for this evaluation we are limited by the maximum achievable
//!    frequency by Vivado"); the model shows why: resources keep
//!    shrinking by 1/M but the effective clock collapses once
//!    CL1 = M·CL0 exceeds what the fabric can close.
//! 2. **Boundary FIFO depth** — the CDC synchronizer needs enough
//!    slack to ride out cross-domain jitter; too-shallow FIFOs stall
//!    the fast domain (visible in exact-mode cycle counts).
//! 3. **Subdomain size** (paper §3.4) — pumping the whole application
//!    vs only its compute core, measured as plumbing overhead.
//!
//! Run with: `cargo run --release --example ablation`

use temporal_vec::apps;
use temporal_vec::coordinator::{compile, BuildSpec};
use temporal_vec::ir::PumpMode;
use temporal_vec::sim::{run_exact, Hbm};
use temporal_vec::transforms::streaming::StreamingComposition;
use temporal_vec::transforms::{MultiPump, PassManager, Vectorize};
use temporal_vec::util::table::{fnum, pct, Table};
use temporal_vec::util::Rng;

fn main() -> Result<(), String> {
    // ---- 1. pumping-factor sweep ----
    let n: i64 = 1 << 20;
    let mut t = Table::new(
        "ablation 1: pumping factor (vecadd, V=8, resource mode)",
        &["M", "DSP%", "CL0", "CL1", "CL1/M", "effective MHz", "verdict"],
    );
    let base_eff = {
        let c = compile(
            BuildSpec::new(apps::vecadd::build()).vectorized("vadd", 8).bind("N", n),
        )?;
        c.report.effective_mhz
    };
    t.row(vec![
        "1 (orig)".into(),
        "0.56".into(),
        fnum(base_eff, 1),
        "-".into(),
        "-".into(),
        fnum(base_eff, 1),
        "baseline".into(),
    ]);
    for m in [2usize, 4, 8] {
        let c = compile(
            BuildSpec::new(apps::vecadd::build())
                .vectorized("vadd", 8)
                .pumped(m, PumpMode::Resource)
                .bind("N", n),
        )?;
        let cl1 = c.report.cl1.unwrap().achieved_mhz;
        let verdict = if c.report.effective_mhz > 0.9 * base_eff {
            "free resources"
        } else {
            "throughput lost"
        };
        t.row(vec![
            m.to_string(),
            pct(c.report.util_percent()[4]),
            fnum(c.report.cl0.achieved_mhz, 1),
            fnum(cl1, 1),
            fnum(cl1 / m as f64, 1),
            fnum(c.report.effective_mhz, 1),
            verdict.into(),
        ]);
    }
    t.footnote("beyond M=2 the 650 MHz request cap makes CL1/M the bottleneck — the paper's Vivado limit");
    println!("{}", t.render());

    // ---- 2. boundary FIFO depth (exact-mode stalls) ----
    let n2: i64 = 1 << 12;
    let mut t2 = Table::new(
        "ablation 2: CDC stream depth (vecadd V=4 DP, exact simulation)",
        &["depth", "slow cycles", "overhead vs deep"],
    );
    let mut results = Vec::new();
    for depth in [1usize, 2, 4, 16, 64] {
        let mut g = apps::vecadd::build();
        let mut pm = PassManager::new();
        pm.run(&mut g, &Vectorize::new("vadd", 4))?;
        pm.run(&mut g, &StreamingComposition { stream_depth: depth })?;
        pm.run(&mut g, &MultiPump::resource(2))?;
        let env = g.bind(&[("N", n2)])?;
        let design =
            temporal_vec::codegen::lower(&g, &env, &temporal_vec::hw::cost::CostModel::default())?;
        let mut rng = Rng::new(4);
        let mut hbm = Hbm::new();
        hbm.load("x", rng.f32_vec(n2 as usize));
        hbm.load("y", rng.f32_vec(n2 as usize));
        let out = run_exact(&design, hbm, 50_000_000)?;
        results.push((depth, out.stats.slow_cycles));
    }
    let deep = results.last().unwrap().1 as f64;
    for (depth, cycles) in &results {
        t2.row(vec![
            depth.to_string(),
            cycles.to_string(),
            format!("{:+.1}%", (*cycles as f64 / deep - 1.0) * 100.0),
        ]);
    }
    t2.footnote("finding: with in-order process scheduling even depth-1 FIFOs sustain rate for a linear chain — the synchronizer latency, not capacity, is what CDC costs here");
    println!("{}", t2.render());

    // ---- 3. plumbing overhead vs subdomain size (paper §3.4) ----
    let mut t3 = Table::new(
        "ablation 3: plumbing overhead by boundary width (vecadd V, DP)",
        &["V", "plumbing LUT", "plumbing regs", "share of design LUT"],
    );
    for v in [2usize, 4, 8, 16] {
        let c = compile(
            BuildSpec::new(apps::vecadd::build())
                .vectorized("vadd", v)
                .pumped(2, PumpMode::Resource)
                .bind("N", n),
        )?;
        let plumbing: temporal_vec::hw::ResourceVec = c
            .design
            .modules
            .iter()
            .filter(|m| match &m.spec {
                temporal_vec::codegen::ModuleSpec::Sync { input, .. } => {
                    !input.starts_with("__ctrl")
                }
                temporal_vec::codegen::ModuleSpec::Issuer { .. }
                | temporal_vec::codegen::ModuleSpec::Packer { .. } => true,
                _ => false,
            })
            .fold(temporal_vec::hw::ResourceVec::ZERO, |acc, m| acc + m.resources);
        t3.row(vec![
            v.to_string(),
            fnum(plumbing.lut_logic, 0),
            fnum(plumbing.registers, 0),
            pct(plumbing.lut_logic / c.report.resources.lut_logic * 100.0),
        ]);
    }
    t3.footnote("wider boundaries cost more plumbing — why the paper pumps the LARGEST streamable subgraph (fewest crossings), §3.4");
    println!("{}", t3.render());
    Ok(())
}
