//! End-to-end quickstart: the full three-layer stack on one workload.
//!
//! 1. write the paper's running example (`z = x + y`) in the DSL
//!    frontend;
//! 2. apply the automatic transformations: vectorize ×8 → streaming
//!    composition → **multi-pumping** (resource mode, M=2);
//! 3. lower to a design netlist, price it on the U280 model, and print
//!    the paper-style report (clocks + utilization);
//! 4. simulate the design *functionally on real data* and cross-check
//!    the result against the AOT-compiled JAX/Pallas golden model
//!    executed through PJRT — proving the compiler, the simulator and
//!    the L1/L2 artifacts all agree;
//! 5. emit the HLS C++ and the four RTL kernel files (paper §3.3).
//!
//! Run with: `cargo run --release --example quickstart`
//! (artifacts must exist: `make artifacts`).

use temporal_vec::coordinator::{compile, BuildSpec};
use temporal_vec::ir::PumpMode;
use temporal_vec::runtime::{artifact, GoldenRunner};
use temporal_vec::sim::{rate_model, run_functional, Hbm};
use temporal_vec::util::Rng;

const PROGRAM: &str = "
program vecadd(N):
  x: f32[N] @ hbm
  y: f32[N] @ hbm
  z: f32[N] @ hbm
  map i in 0:N:
    z[i] = x[i] + y[i]
";

fn main() -> Result<(), String> {
    let n: i64 = 4096; // matches the AOT golden artifact

    println!("=== 1. frontend: parsing the paper's running example ===");
    let sdfg = temporal_vec::frontend::compile(PROGRAM)?;
    println!("{}", temporal_vec::ir::printer::to_text(&sdfg));

    println!("=== 2+3. transform pipeline: vectorize -> stream -> multi-pump ===");
    let c = compile(
        BuildSpec::new(sdfg)
            .vectorized("map0", 8)
            .pumped(2, PumpMode::Resource)
            .bind("N", n),
    )?;
    for line in &c.pass_log {
        println!("  pass {line}");
    }
    let u = c.report.util_percent();
    println!(
        "\ndesign report: CL0 {:.1} MHz, CL1 {:.1} MHz, effective {:.1} MHz",
        c.report.cl0.achieved_mhz,
        c.report.cl1.unwrap().achieved_mhz,
        c.report.effective_mhz
    );
    println!(
        "utilization:   LUT {:.2}% | LUTMem {:.2}% | Regs {:.2}% | BRAM {:.2}% | DSP {:.2}%",
        u[0], u[1], u[2], u[3], u[4]
    );
    let cycles = rate_model(&c.design);
    println!(
        "cycle model:   {} slow cycles -> {:.3} ms at the effective clock\n",
        cycles.slow_cycles,
        cycles.seconds_at(c.report.effective_mhz) * 1e3
    );

    println!("=== 4. functional simulation vs PJRT golden model ===");
    let mut rng = Rng::new(42);
    let x = rng.f32_vec(n as usize);
    let y = rng.f32_vec(n as usize);
    let mut hbm = Hbm::new();
    hbm.load("x", x.clone());
    hbm.load("y", y.clone());
    let sim_out = run_functional(&c.design, hbm)?;
    let got = sim_out.hbm.read("z");

    let mut runner = GoldenRunner::new(&artifact::artifacts_dir())?;
    println!("PJRT platform: {}", runner.platform());
    let want = runner.run("vecadd", &[&x, &y])?;
    assert_eq!(got.len(), want.len());
    let worst = got
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w).abs())
        .fold(0.0f32, f32::max);
    println!("simulated z == golden z: {} elements, max abs err {worst:.2e}", got.len());
    assert!(worst < 1e-5, "simulator diverged from the golden model");

    println!("\n=== 5. generated artifacts (paper §3.3) ===");
    let cpp = temporal_vec::codegen::hls::emit_hls(&c.design);
    let rtl = temporal_vec::codegen::rtl::emit_rtl(&c.design);
    println!(
        "HLS C++: {} bytes; RTL: controller {} B, core {} B, top {} B, tcl {} B",
        cpp.len(),
        rtl.controller_sv.len(),
        rtl.core_sv.len(),
        rtl.toplevel_v.len(),
        rtl.package_tcl.len()
    );
    println!("link.cfg:\n{}", rtl.link_cfg);

    println!("quickstart OK — all three layers agree.");
    Ok(())
}
