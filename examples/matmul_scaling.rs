//! Scenario: scaling the systolic GEMM with the resources multi-pumping
//! frees (paper §4.2).
//!
//! Sweeps processing-element counts for the original and double-pumped
//! designs, prints which configurations fit a single SLR, and verifies
//! the functional output of the double-pumped design against the PJRT
//! golden model at artifact scale.
//!
//! Run with: `cargo run --release --example matmul_scaling`

use temporal_vec::apps::matmul;
use temporal_vec::coordinator::{compile, BuildSpec};
use temporal_vec::hw::Device;
use temporal_vec::ir::PumpMode;
use temporal_vec::runtime::{artifact, GoldenRunner};
use temporal_vec::sim::{rate_model, run_functional, Hbm};
use temporal_vec::util::table::{fnum, pct, Table};
use temporal_vec::util::Rng;

fn main() -> Result<(), String> {
    let nmk = matmul::PAPER_NMK;
    let pool = Device::u280().slr0_pool();
    let flops = matmul::flops(nmk, nmk, nmk);

    println!("PE scaling sweep at {nmk}^3 (f32, vec width {}):\n", matmul::VEC_WIDTH);
    let mut t = Table::new(
        "systolic GEMM: original vs double-pumped PE scaling",
        &["PEs", "variant", "DSP%", "BRAM%", "fits SLR", "eff MHz", "GOp/s"],
    );
    for &pes in &[16usize, 32, 48, 64, 80] {
        for pump in [false, true] {
            let mut spec = BuildSpec::new(matmul::build(pes)).cl0(270.0);
            for (s, v) in matmul::bindings(nmk) {
                spec = spec.bind(&s, v);
            }
            if pump {
                spec = spec.pumped(2, PumpMode::Resource);
            }
            let c = compile(spec)?;
            let fits = c.report.resources.fits(&pool);
            let stats = rate_model(&c.design);
            let gops = flops / stats.seconds_at(c.report.effective_mhz) / 1e9;
            t.row(vec![
                pes.to_string(),
                if pump { "DP" } else { "O" }.into(),
                pct(c.report.util_percent()[4]),
                pct(c.report.util_percent()[3]),
                if fits { "yes" } else { "NO" }.into(),
                fnum(c.report.effective_mhz, 1),
                if fits { fnum(gops, 1) } else { "-".into() },
            ]);
        }
    }
    t.footnote("the paper's point: DP frees ~50 % DSP/BRAM, so 64 PEs fit where O tops out near 32");
    println!("{}", t.render());

    // functional check at artifact scale (128^3) for the pumped design
    println!("functional check (128^3, double-pumped) vs PJRT golden model...");
    let n = matmul::GOLDEN_NMK;
    let mut spec = BuildSpec::new(matmul::build(4)).pumped(2, PumpMode::Resource);
    for (s, v) in matmul::bindings(n) {
        spec = spec.bind(&s, v);
    }
    let c = compile(spec)?;
    let mut rng = Rng::new(7);
    let a = rng.f32_vec((n * n) as usize);
    let b = rng.f32_vec((n * n) as usize);
    let mut hbm = Hbm::new();
    hbm.load("A", a.clone());
    hbm.load("B", b.clone());
    let out = run_functional(&c.design, hbm)?;
    let got = out.hbm.read("C");
    let mut runner = GoldenRunner::new(&artifact::artifacts_dir())?;
    let want = runner.run("matmul", &[&a, &b])?;
    let worst = got
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w).abs() / w.abs().max(1.0))
        .fold(0.0f32, f32::max);
    println!("max rel err vs golden: {worst:.2e}");
    assert!(worst < 1e-4);
    println!("matmul_scaling OK");
    Ok(())
}
